//! Request / response types and shape buckets.
//!
//! HLO executables are shape-specialised, so the dynamic batcher routes
//! requests into *buckets* — one per (C, H, W, kchunk, tap-mode) scan
//! geometry — and fuses same-bucket requests into the largest compiled
//! batch artifact that fits (`scan_h{H}w{W}c{C}n{N}` entries from the
//! manifest).

use std::ops::Deref;
use std::sync::{mpsc, Weak};
use std::time::{Duration, Instant};

use crate::runtime::Value;
use crate::scan::kchunk_valid;
use crate::util::BufferPool;
use crate::Tensor;

/// Priority class carried by every request. Admission-time load
/// shedding only ever drops [`Priority::Low`] traffic; `High` and
/// `Normal` keep their latency budget and are refused only by the hard
/// queue cap (backpressure).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    High,
    Normal,
    Low,
}

impl Default for Priority {
    fn default() -> Self {
        Priority::Normal
    }
}

impl Priority {
    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Low];

    /// Dense index for per-class metric arrays.
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }
}

/// Per-request submission options: priority class, an optional explicit
/// deadline (relative to submission; defaults to the class SLO budget
/// from the `[serve]` config when unset), and a tenant id for quota
/// accounting. `Default` is a normal-priority, deadline-less request of
/// tenant 0 — exactly the behaviour `submit_scan` always had.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOptions {
    pub priority: Priority,
    pub deadline: Option<Duration>,
    pub tenant: u64,
}

/// Structured per-request failure delivered *through the reply channel*
/// (unlike [`SubmitError`], which rejects at the submit call). Clients
/// recover it with `err.downcast_ref::<RequestError>()`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestError {
    /// The request's deadline passed before execution started; it was
    /// shed instead of being executed dead.
    Deadline,
    /// Load shedding dropped this request under overload.
    Shed,
    /// The coordinator shut down before this request could execute.
    Closed,
    /// The request's scan-workspace footprint exceeds
    /// `serve.max_request_mb` and tiling is disabled, so the
    /// coordinator cannot bound its peak memory. Enabling tiling (a
    /// non-zero workspace cap with `scan.plan = auto`, or forcing
    /// `scan.plan = tiled`) admits the same geometry as a stream of
    /// row-band tiles instead.
    TooLarge {
        /// The untiled footprint the planner priced (MiB, rounded up).
        need_mb: u64,
        /// The configured `serve.max_request_mb` admission cap.
        cap_mb: u64,
    },
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Deadline => write!(f, "deadline exceeded before execution"),
            RequestError::Shed => write!(f, "shed under overload"),
            RequestError::Closed => write!(f, "coordinator closed before execution"),
            RequestError::TooLarge { need_mb, cap_mb } => write!(
                f,
                "request workspace footprint {need_mb} MiB exceeds \
                 serve.max_request_mb = {cap_mb} and tiling is disabled"
            ),
        }
    }
}

impl std::error::Error for RequestError {}

/// Scan-geometry bucket key.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Bucket {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub kchunk: usize,
    /// Per-channel taps (GSPN-1 semantics) vs channel-shared.
    pub per_channel: bool,
}

impl Bucket {
    /// Manifest entry name for this bucket at batch size n.
    pub fn artifact(&self, n: usize) -> String {
        let mut s = format!("scan_h{}w{}c{}n{}", self.h, self.w, self.c, n);
        if self.kchunk > 0 {
            s.push_str(&format!("k{}", self.kchunk));
        }
        if self.per_channel {
            s.push_str("pc");
        }
        s
    }
}

/// The payload of one inference request.
#[derive(Clone, Debug)]
pub enum Payload {
    /// One single-sample GSPN scan: x (1,C,H,W), a_raw (1,Cw,3,H,W),
    /// lam (1,C,H,W). Batchable with same-bucket peers.
    Scan { x: Tensor, a_raw: Tensor, lam: Tensor },
    /// Direct execution of a named artifact (not batched).
    Direct { artifact: String, inputs: Vec<Value> },
}

/// Admission-time validation of a scan request's geometry. Rejecting
/// here turns what used to be a worker-side panic (`scan_l2r`'s
/// `assert!(w % kchunk == 0)`, or an HLO shape mismatch deep in PJRT)
/// into a structured [`SubmitError::Invalid`] at the submit call.
pub fn validate_scan_shapes(
    x: &Tensor,
    a_raw: &Tensor,
    lam: &Tensor,
    kchunk: usize,
) -> Result<(), String> {
    if x.rank() != 4 {
        return Err(format!("x must be (1, C, H, W), got rank {}", x.rank()));
    }
    if x.shape[0] != 1 {
        return Err(format!("scan requests are single-sample: N must be 1, got {}", x.shape[0]));
    }
    if lam.shape != x.shape {
        return Err(format!("lam shape {:?} must match x shape {:?}", lam.shape, x.shape));
    }
    let (c, h, w) = (x.shape[1], x.shape[2], x.shape[3]);
    if c == 0 || h == 0 || w == 0 {
        // Degenerate geometry: W=0 in particular would reach the
        // `w % chunk` remainder in the scan with a zero divisor.
        return Err(format!("x dims must be non-zero, got (1, {c}, {h}, {w})"));
    }
    if a_raw.rank() != 5 || a_raw.shape[0] != 1 || a_raw.shape[2] != 3 {
        return Err(format!("a_raw must be (1, Cw, 3, H, W), got {:?}", a_raw.shape));
    }
    if a_raw.shape[3] != h || a_raw.shape[4] != w {
        return Err(format!(
            "a_raw spatial dims {:?} must match x ({h}, {w})",
            &a_raw.shape[3..]
        ));
    }
    if a_raw.shape[1] != 1 && a_raw.shape[1] != c {
        return Err(format!("a_raw Cw={} must be 1 or C={c}", a_raw.shape[1]));
    }
    if !kchunk_valid(w, kchunk) {
        return Err(format!("kchunk={kchunk} must be 0 or divide W={w}"));
    }
    Ok(())
}

impl Payload {
    /// Bucket for a scan payload (None for direct requests).
    pub fn bucket(&self, kchunk: usize) -> Option<Bucket> {
        match self {
            Payload::Scan { x, a_raw, .. } => Some(Bucket {
                c: x.shape[1],
                h: x.shape[2],
                w: x.shape[3],
                kchunk,
                per_channel: a_raw.shape[1] == x.shape[1] && x.shape[1] > 1,
            }),
            Payload::Direct { .. } => None,
        }
    }
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub payload: Payload,
    pub kchunk: usize,
    pub arrived: Instant,
    pub priority: Priority,
    /// Absolute deadline, resolved at admission from
    /// [`SubmitOptions::deadline`] or the class SLO budget. `None` =
    /// no deadline (never expires, releases purely by age).
    pub deadline: Option<Instant>,
    pub tenant: u64,
    pub reply: mpsc::Sender<Response>,
}

impl Request {
    /// Effective release instant for deadline-aware batching: a
    /// deadline-less request releases when it has aged `max_wait`; a
    /// deadlined one releases at least `max_wait` *before* its deadline
    /// (clamped to its arrival), so it still has the wait budget left
    /// to execute rather than being released exactly as it expires.
    pub fn release_at(&self, max_wait: Duration) -> Instant {
        let aged = self.arrived + max_wait;
        match self.deadline {
            Some(d) => aged.min(d.checked_sub(max_wait).unwrap_or(self.arrived)),
            None => aged,
        }
    }

    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| now >= d)
    }
}

/// A successful reply's output values, with their f32 storage on loan
/// from the coordinator's workspace pool.
///
/// Derefs to the value slice, so clients index it exactly like the
/// plain `Vec<Value>` it replaces (`resp.result?[0].as_f32()`). What
/// changes is the buffer's afterlife: on drop, each tensor's backing
/// vec is donated back to the workspace it was taken from
/// ([`BufferPool::donate`]) — if the coordinator is still alive — so
/// the *next* same-bucket reply is served from the pool instead of the
/// allocator. Together with [`BufferPool::take_zeroed`] on the server
/// side this closes the last per-request allocation: client drops the
/// reply, the buffer circles back, the warm bucket stays miss-free.
///
/// Holding the lease past coordinator shutdown is fine (the `Weak`
/// handle just fails to upgrade and the buffer frees normally), as is
/// keeping the values forever via [`ReplyLease::into_values`].
pub struct ReplyLease {
    values: Vec<Value>,
    pool: Weak<BufferPool>,
}

impl ReplyLease {
    pub(crate) fn new(values: Vec<Value>, pool: Weak<BufferPool>) -> ReplyLease {
        ReplyLease { values, pool }
    }

    /// A lease with no pool behind it — replies whose buffers did not
    /// come from a workspace (e.g. PJRT direct execution). Dropping it
    /// is a plain deallocation.
    pub(crate) fn unpooled(values: Vec<Value>) -> ReplyLease {
        ReplyLease { values, pool: Weak::new() }
    }

    /// Keep the values, skip the donation — the escape hatch for
    /// clients that need the tensors to outlive the reply cheaply.
    pub fn into_values(mut self) -> Vec<Value> {
        std::mem::take(&mut self.values)
    }
}

impl Deref for ReplyLease {
    type Target = [Value];
    fn deref(&self) -> &[Value] {
        &self.values
    }
}

impl std::fmt::Debug for ReplyLease {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("ReplyLease").field(&self.values).finish()
    }
}

impl Drop for ReplyLease {
    fn drop(&mut self) {
        if self.values.is_empty() {
            return;
        }
        let Some(pool) = self.pool.upgrade() else { return };
        for v in self.values.drain(..) {
            // `donate` drops foreign-capacity buffers itself, so any
            // tensor is safe to offer.
            if let Value::F32(t) = v {
                pool.donate(t.data);
            }
        }
    }
}

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    /// Output values of a successful execution, their storage leased
    /// from the coordinator workspace (see [`ReplyLease`] — indexes
    /// like the plain `Vec<Value>` and recycles itself on drop).
    pub result: anyhow::Result<ReplyLease>,
    /// Time spent waiting in the queue.
    pub queue_us: u64,
    /// Time in the executor (per-batch, shared across the batch).
    pub execute_us: u64,
    /// Batch size this request was fused into.
    pub batch: usize,
}

/// Errors surfaced to the submitting client.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue full — admission rejected (backpressure).
    Backpressure,
    /// Coordinator is draining / stopped.
    Closed,
    /// No compiled artifact covers this request's geometry.
    UnknownBucket(String),
    /// Malformed request (bad shapes or kchunk), rejected at admission.
    Invalid(String),
    /// Load shedding: the coordinator is over its SLO watermark and
    /// this request's class is sheddable (low priority).
    Shed,
    /// The tenant's token-bucket quota is exhausted.
    Quota(u64),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "coordinator closed"),
            SubmitError::UnknownBucket(b) => write!(f, "no artifact for bucket {b}"),
            SubmitError::Invalid(why) => write!(f, "invalid request: {why}"),
            SubmitError::Shed => write!(f, "shed under overload"),
            SubmitError::Quota(t) => write!(f, "tenant {t} over quota"),
        }
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bucket_artifact_names() {
        let b = Bucket { c: 8, h: 64, w: 64, kchunk: 0, per_channel: false };
        assert_eq!(b.artifact(1), "scan_h64w64c8n1");
        assert_eq!(b.artifact(4), "scan_h64w64c8n4");
        let bk = Bucket { kchunk: 16, ..b.clone() };
        assert_eq!(bk.artifact(1), "scan_h64w64c8n1k16");
        let bp = Bucket { per_channel: true, ..b };
        assert_eq!(bp.artifact(1), "scan_h64w64c8n1pc");
    }

    #[test]
    fn payload_bucket_derivation() {
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&[1, 8, 64, 32], &mut rng, 1.0);
        let shared = Tensor::randn(&[1, 1, 3, 64, 32], &mut rng, 1.0);
        let lam = x.clone();
        let p = Payload::Scan { x: x.clone(), a_raw: shared, lam: lam.clone() };
        let b = p.bucket(0).unwrap();
        assert_eq!((b.c, b.h, b.w, b.per_channel), (8, 64, 32, false));

        let perch = Tensor::randn(&[1, 8, 3, 64, 32], &mut rng, 1.0);
        let p2 = Payload::Scan { x, a_raw: perch, lam };
        assert!(p2.bucket(0).unwrap().per_channel);
    }

    #[test]
    fn direct_has_no_bucket() {
        let p = Payload::Direct { artifact: "classifier_fwd_b8".into(), inputs: vec![] };
        assert!(p.bucket(0).is_none());
    }

    #[test]
    fn admission_validation_accepts_good_requests() {
        let x = Tensor::zeros(&[1, 8, 64, 64]);
        let a = Tensor::zeros(&[1, 1, 3, 64, 64]);
        let lam = Tensor::zeros(&[1, 8, 64, 64]);
        assert!(validate_scan_shapes(&x, &a, &lam, 0).is_ok());
        assert!(validate_scan_shapes(&x, &a, &lam, 16).is_ok());
        let apc = Tensor::zeros(&[1, 8, 3, 64, 64]);
        assert!(validate_scan_shapes(&x, &apc, &lam, 0).is_ok());
    }

    #[test]
    fn admission_validation_rejects_bad_kchunk() {
        // W=64, kchunk=7: the old path panicked a serving worker inside
        // scan_l2r; admission must reject instead.
        let x = Tensor::zeros(&[1, 8, 64, 64]);
        let a = Tensor::zeros(&[1, 1, 3, 64, 64]);
        let lam = Tensor::zeros(&[1, 8, 64, 64]);
        let err = validate_scan_shapes(&x, &a, &lam, 7).unwrap_err();
        assert!(err.contains("kchunk"), "{err}");
        assert!(validate_scan_shapes(&x, &a, &lam, 128).is_err());
    }

    #[test]
    fn admission_validation_rejects_degenerate_dims() {
        // W=0 would hit a zero-divisor remainder inside scan_l2r.
        let x = Tensor::zeros(&[1, 8, 64, 0]);
        let a = Tensor::zeros(&[1, 1, 3, 64, 0]);
        let lam = Tensor::zeros(&[1, 8, 64, 0]);
        let err = validate_scan_shapes(&x, &a, &lam, 0).unwrap_err();
        assert!(err.contains("non-zero"), "{err}");
        assert!(validate_scan_shapes(
            &Tensor::zeros(&[1, 8, 0, 64]),
            &Tensor::zeros(&[1, 1, 3, 0, 64]),
            &Tensor::zeros(&[1, 8, 0, 64]),
            0
        )
        .is_err());
    }

    #[test]
    fn admission_validation_rejects_bad_shapes() {
        let x = Tensor::zeros(&[1, 8, 64, 64]);
        let a = Tensor::zeros(&[1, 1, 3, 64, 64]);
        let lam = Tensor::zeros(&[1, 8, 64, 64]);
        // Wrong rank.
        assert!(validate_scan_shapes(&Tensor::zeros(&[8, 64, 64]), &a, &lam, 0).is_err());
        // Batched payload (N must be 1 at submit).
        assert!(validate_scan_shapes(&Tensor::zeros(&[2, 8, 64, 64]), &a, &lam, 0).is_err());
        // lam mismatch.
        assert!(validate_scan_shapes(&x, &a, &Tensor::zeros(&[1, 8, 64, 32]), 0).is_err());
        // a_raw wrong tap count / spatial dims / Cw.
        assert!(validate_scan_shapes(&x, &Tensor::zeros(&[1, 1, 2, 64, 64]), &lam, 0).is_err());
        assert!(validate_scan_shapes(&x, &Tensor::zeros(&[1, 1, 3, 32, 64]), &lam, 0).is_err());
        assert!(validate_scan_shapes(&x, &Tensor::zeros(&[1, 4, 3, 64, 64]), &lam, 0).is_err());
    }

    #[test]
    fn invalid_submit_error_displays_reason() {
        let e = SubmitError::Invalid("kchunk=7 must be 0 or divide W=64".into());
        assert!(e.to_string().contains("kchunk=7"));
        assert!(SubmitError::Shed.to_string().contains("shed"));
        assert!(SubmitError::Quota(7).to_string().contains("tenant 7"));
    }

    fn mk_request(arrived: Instant, deadline: Option<Instant>) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id: 0,
            payload: Payload::Direct { artifact: "t".into(), inputs: vec![] },
            kchunk: 0,
            arrived,
            priority: Priority::default(),
            deadline,
            tenant: 0,
            reply: tx,
        }
    }

    #[test]
    fn release_at_orders_by_effective_deadline() {
        let t0 = Instant::now();
        let w = Duration::from_micros(1_000);
        // No deadline: release by age.
        let r = mk_request(t0, None);
        assert_eq!(r.release_at(w), t0 + w);
        assert!(!r.expired(t0 + Duration::from_secs(3600)));
        // Far deadline: age still wins (min).
        let far = mk_request(t0, Some(t0 + Duration::from_secs(1)));
        assert_eq!(far.release_at(w), t0 + w);
        // Tight deadline: release a max_wait margin before it.
        let tight = mk_request(t0, Some(t0 + Duration::from_micros(1_500)));
        assert_eq!(tight.release_at(w), t0 + Duration::from_micros(500));
        // Deadline inside one max_wait of arrival: release immediately
        // (clamped to arrival, never later than the aged instant).
        let hot = mk_request(t0, Some(t0 + Duration::from_micros(200)));
        assert!(hot.release_at(w) <= t0);
        assert!(hot.expired(t0 + Duration::from_micros(200)));
        assert!(!hot.expired(t0));
    }

    #[test]
    fn priority_index_and_labels_are_dense() {
        assert_eq!(Priority::default(), Priority::Normal);
        for (i, p) in Priority::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
        }
        assert_eq!(Priority::High.label(), "high");
        assert_eq!(Priority::Low.label(), "low");
        let opts = SubmitOptions::default();
        assert_eq!((opts.priority, opts.deadline, opts.tenant), (Priority::Normal, None, 0));
    }

    #[test]
    fn request_error_displays_and_downcasts() {
        let e = anyhow::Error::new(RequestError::Shed);
        assert_eq!(e.downcast_ref::<RequestError>(), Some(&RequestError::Shed));
        assert!(RequestError::Deadline.to_string().contains("deadline"));
        assert!(RequestError::Closed.to_string().contains("closed"));
        let big = RequestError::TooLarge { need_mb: 600, cap_mb: 256 };
        let e = anyhow::Error::new(big);
        assert_eq!(e.downcast_ref::<RequestError>(), Some(&big));
        let msg = big.to_string();
        assert!(msg.contains("600 MiB"), "{msg}");
        assert!(msg.contains("max_request_mb = 256"), "{msg}");
    }
}
