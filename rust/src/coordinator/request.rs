//! Request / response types and shape buckets.
//!
//! HLO executables are shape-specialised, so the dynamic batcher routes
//! requests into *buckets* — one per (C, H, W, kchunk, tap-mode) scan
//! geometry — and fuses same-bucket requests into the largest compiled
//! batch artifact that fits (`scan_h{H}w{W}c{C}n{N}` entries from the
//! manifest).

use std::sync::mpsc;
use std::time::Instant;

use crate::runtime::Value;
use crate::Tensor;

/// Scan-geometry bucket key.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Bucket {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub kchunk: usize,
    /// Per-channel taps (GSPN-1 semantics) vs channel-shared.
    pub per_channel: bool,
}

impl Bucket {
    /// Manifest entry name for this bucket at batch size n.
    pub fn artifact(&self, n: usize) -> String {
        let mut s = format!("scan_h{}w{}c{}n{}", self.h, self.w, self.c, n);
        if self.kchunk > 0 {
            s.push_str(&format!("k{}", self.kchunk));
        }
        if self.per_channel {
            s.push_str("pc");
        }
        s
    }
}

/// The payload of one inference request.
#[derive(Clone, Debug)]
pub enum Payload {
    /// One single-sample GSPN scan: x (1,C,H,W), a_raw (1,Cw,3,H,W),
    /// lam (1,C,H,W). Batchable with same-bucket peers.
    Scan { x: Tensor, a_raw: Tensor, lam: Tensor },
    /// Direct execution of a named artifact (not batched).
    Direct { artifact: String, inputs: Vec<Value> },
}

impl Payload {
    /// Bucket for a scan payload (None for direct requests).
    pub fn bucket(&self, kchunk: usize) -> Option<Bucket> {
        match self {
            Payload::Scan { x, a_raw, .. } => Some(Bucket {
                c: x.shape[1],
                h: x.shape[2],
                w: x.shape[3],
                kchunk,
                per_channel: a_raw.shape[1] == x.shape[1] && x.shape[1] > 1,
            }),
            Payload::Direct { .. } => None,
        }
    }
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub payload: Payload,
    pub kchunk: usize,
    pub arrived: Instant,
    pub reply: mpsc::Sender<Response>,
}

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub result: anyhow::Result<Vec<Value>>,
    /// Time spent waiting in the queue.
    pub queue_us: u64,
    /// Time in the executor (per-batch, shared across the batch).
    pub execute_us: u64,
    /// Batch size this request was fused into.
    pub batch: usize,
}

/// Errors surfaced to the submitting client.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue full — admission rejected (backpressure).
    Backpressure,
    /// Coordinator is draining / stopped.
    Closed,
    /// No compiled artifact covers this request's geometry.
    UnknownBucket(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "coordinator closed"),
            SubmitError::UnknownBucket(b) => write!(f, "no artifact for bucket {b}"),
        }
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bucket_artifact_names() {
        let b = Bucket { c: 8, h: 64, w: 64, kchunk: 0, per_channel: false };
        assert_eq!(b.artifact(1), "scan_h64w64c8n1");
        assert_eq!(b.artifact(4), "scan_h64w64c8n4");
        let bk = Bucket { kchunk: 16, ..b.clone() };
        assert_eq!(bk.artifact(1), "scan_h64w64c8n1k16");
        let bp = Bucket { per_channel: true, ..b };
        assert_eq!(bp.artifact(1), "scan_h64w64c8n1pc");
    }

    #[test]
    fn payload_bucket_derivation() {
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&[1, 8, 64, 32], &mut rng, 1.0);
        let shared = Tensor::randn(&[1, 1, 3, 64, 32], &mut rng, 1.0);
        let lam = x.clone();
        let p = Payload::Scan { x: x.clone(), a_raw: shared, lam: lam.clone() };
        let b = p.bucket(0).unwrap();
        assert_eq!((b.c, b.h, b.w, b.per_channel), (8, 64, 32, false));

        let perch = Tensor::randn(&[1, 8, 3, 64, 32], &mut rng, 1.0);
        let p2 = Payload::Scan { x, a_raw: perch, lam };
        assert!(p2.bucket(0).unwrap().per_channel);
    }

    #[test]
    fn direct_has_no_bucket() {
        let p = Payload::Direct { artifact: "classifier_fwd_b8".into(), inputs: vec![] };
        assert!(p.bucket(0).is_none());
    }
}
