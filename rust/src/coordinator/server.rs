//! The serving coordinator: admission control, shape-bucketed dynamic
//! batching, and a pool of executor workers driving PJRT engines.
//!
//! Shape: a vLLM-router-like front end for GSPN inference. Clients call
//! `submit_scan` (single-sample scan requests, fused into batched
//! executables) or `submit_direct` (whole-artifact calls). Each worker
//! thread owns its own `Engine` (the xla wrapper types are not `Send`,
//! so executor workers must stay dedicated OS threads); the shared state
//! is only the batcher, the direct queue, and metrics.
//!
//! CPU-side work on the serving path (fused-batch input assembly) runs
//! on the process-wide [`ThreadPool::global`] — the same substrate the
//! scan reference and the benches use — never on ad-hoc threads.
//! Requests are validated at admission via `validate_scan_shapes`: a
//! malformed shape or kchunk comes back as [`SubmitError::Invalid`]
//! instead of panicking an executor.
//!
//! ## Overload robustness (SLO-aware admission and degradation)
//!
//! Every request carries a priority class, an optional deadline, and a
//! tenant id ([`SubmitOptions`] via [`Coordinator::submit_scan_with`]);
//! deadlines default to the class SLO budget (`[serve] slo_*_us`).
//! Admission applies, in order: shape validation, per-tenant
//! token-bucket quotas (`quota_rps`/`quota_burst` →
//! [`SubmitError::Quota`]), and load shedding — when the queue sits
//! above `shed_queue_frac` of `queue_cap` *or* the rolling error budget
//! (fraction of recent completions violating `slo_p99_us`) exceeds
//! `slo_error_budget`, low-priority requests are refused with
//! [`SubmitError::Shed`]. High/normal traffic is never shed; it is only
//! bounded by the hard `queue_cap` backpressure, which is how
//! high-priority p99 stays bounded while low-priority degrades first.
//! Queued requests whose deadline passes are shed by the batcher at pop
//! time and answered with a structured `Deadline` error reply through
//! their channel — never executed dead, never left hanging — and
//! [`Coordinator::shutdown`] resolves every request still queued after
//! the workers drain with a structured `Closed` reply.
//!
//! Two execution backends ([`ServeConfig::backend`]):
//!
//! * `"pjrt"` — compiled HLO artifacts; buckets come from the manifest
//!   and each worker owns a PJRT engine.
//! * `"cpu"` — the column-staged fused scan engine
//!   ([`crate::scan::fused`]) serves scan requests directly: no
//!   artifacts, no manifest, any valid geometry (buckets register on
//!   first use), plane-block parallelism on the shared pool. This is
//!   the pure-Rust serving path — bit-identical to `scan_l2r` — and
//!   what the coordinator e2e tests exercise without artifacts.
//!
//! ## Bounded-memory high-resolution serving (tiled streaming)
//!
//! The cpu-fused path prices every bucket's workspace demand on one
//! path ([`Coordinator::planned_bucket`]): the planner's decision
//! wrapped by the engine's own tiling guard
//! ([`crate::scan::plan::maybe_tile`]) against the coordinator's
//! workspace cap (`workspace_cap_mb`). A geometry whose full-frame
//! footprint exceeds the cap therefore executes as a stream of
//! row-band tiles ([`crate::scan::plan::ScanStrategy::Tiled`], band
//! height `[scan] tile_band_rows`), each band leasing and returning
//! its scratch before the next begins, so the request's peak workspace
//! is bounded by one band instead of the frame — bit-identical output,
//! the carry crossing bands through the serialized
//! [`crate::scan::engine::ExternalCarry`] boundary.
//!
//! `serve.max_request_mb` adds the per-request admission cap on that
//! same planned (post-tiling) demand: an over-cap request is answered
//! with a structured [`RequestError::TooLarge`] *reply* naming the
//! demand and the cap — counted under `rej_too_large`, refused before
//! bucket registration and pre-warm so it can never fill free lists
//! past the pool cap. With tiling enabled the same geometry prices at
//! its per-band footprint and is admitted. Per-request peak workspace
//! is measured by bracketing each execution with
//! [`BufferPool::rebase_peak`] and surfaces in the metrics report
//! (`per-request peak workspace: mean/max`).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{
    validate_scan_shapes, Bucket, Payload, Priority, ReplyLease, Request, RequestError,
    Response, SubmitError, SubmitOptions,
};
use crate::config::ServeConfig;
use crate::runtime::{Engine, Manifest, Value};
use crate::scan::plan::{
    eager_release_min_slo, maybe_tile, plan_scan, workspace_footprint, ScanGeometry, ScanPlan,
};
use crate::tensor::{concat_axis0, split_axis0};
use crate::util::{lock_unpoisoned, logging, BufferPool, PoolStats, ThreadPool};
use crate::Tensor;

/// Execution backend selected by [`ServeConfig::backend`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Backend {
    Pjrt,
    CpuFused,
}

/// Compiled view of the `[serve]` SLO knobs: per-class latency budgets
/// (zero ⇒ no default deadline for that class), the tolerated fraction
/// of SLO-violating completions, and the queue depth above which
/// low-priority admission starts shedding (zero ⇒ depth shedding off).
struct SloPolicy {
    high: Option<Duration>,
    normal: Option<Duration>,
    low: Option<Duration>,
    error_budget: f64,
    shed_depth: usize,
    /// Whether `slo_p99_us` is configured — gates the error-budget
    /// overload check so unconfigured servers never take the metrics
    /// lock on the admission path.
    p99_set: bool,
}

impl SloPolicy {
    fn from_cfg(cfg: &ServeConfig) -> SloPolicy {
        let budget = |us: u64| (us > 0).then(|| Duration::from_micros(us));
        let shed_depth = if cfg.queue_cap > 0 && cfg.shed_queue_frac > 0.0 {
            ((cfg.queue_cap as f64 * cfg.shed_queue_frac).ceil() as usize).max(1)
        } else {
            0
        };
        SloPolicy {
            high: budget(cfg.slo_high_us),
            normal: budget(cfg.slo_normal_us),
            low: budget(cfg.slo_low_us),
            error_budget: cfg.slo_error_budget,
            shed_depth,
            p99_set: cfg.slo_p99_us > 0,
        }
    }

    fn class_budget(&self, p: Priority) -> Option<Duration> {
        match p {
            Priority::High => self.high,
            Priority::Normal => self.normal,
            Priority::Low => self.low,
        }
    }
}

/// Per-tenant token buckets for admission quotas (`quota_rps` refill,
/// `quota_burst` capacity). `rate <= 0` disables quotas entirely.
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

struct QuotaState {
    rate: f64,
    burst: f64,
    buckets: HashMap<u64, TokenBucket>,
}

/// Cap on tracked tenants. Fully-refilled buckets are evicted first —
/// forgetting one is lossless (a fresh bucket starts at full burst).
const MAX_TENANTS: usize = 4096;

impl QuotaState {
    fn new(rate: f64, burst: usize) -> QuotaState {
        QuotaState { rate, burst: burst.max(1) as f64, buckets: HashMap::new() }
    }

    fn admit(&mut self, tenant: u64, now: Instant) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let (rate, burst) = (self.rate, self.burst);
        if self.buckets.len() >= MAX_TENANTS && !self.buckets.contains_key(&tenant) {
            self.buckets.retain(|_, b| {
                let dt = now.saturating_duration_since(b.last).as_secs_f64();
                b.tokens = (b.tokens + dt * rate).min(burst);
                b.last = now;
                b.tokens < burst
            });
            if self.buckets.len() >= MAX_TENANTS {
                // Every tracked tenant is actively draining its bucket;
                // admit the newcomer untracked (best effort) rather
                // than deny service on table pressure.
                return true;
            }
        }
        let b = self.buckets.entry(tenant).or_insert(TokenBucket { tokens: burst, last: now });
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.last = now;
        b.tokens = (b.tokens + dt * rate).min(burst);
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

struct Shared {
    batcher: Mutex<Batcher>,
    direct: Mutex<VecDeque<Request>>,
    work_ready: Condvar,
    metrics: Mutex<Metrics>,
    shutdown: AtomicBool,
    artifacts_dir: String,
    backend: Backend,
    slo: SloPolicy,
    quotas: Mutex<QuotaState>,
    /// Per-coordinator scratch pool: the cpu-fused path leases every
    /// scan-engine buffer from here, so the allocation-free invariant
    /// (and its hit/miss counters) are isolated per coordinator instead
    /// of shared process-wide. `Arc` so client-held [`ReplyLease`]s can
    /// donate reply buffers back via a `Weak` handle without keeping a
    /// dead coordinator's pool alive.
    workspace: Arc<BufferPool>,
    workspace_prewarm: bool,
    /// Per-request workspace admission cap (`serve.max_request_mb`,
    /// bytes; 0 = none). A request whose planned demand — priced the
    /// way the executor will actually run it, tiling included — exceeds
    /// this is answered with a structured [`RequestError::TooLarge`]
    /// reply instead of queued.
    max_request_bytes: usize,
}

pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Start the coordinator: enumerate scan buckets from the manifest,
    /// then spawn the executor threads (each builds its own PJRT
    /// engine); `cfg.workers == 0` auto-sizes the executor set off the
    /// shared `ThreadPool::global()` width.
    pub fn start(cfg: &ServeConfig) -> anyhow::Result<Coordinator> {
        let backend = match cfg.backend.as_str() {
            "pjrt" => Backend::Pjrt,
            "cpu" | "cpu-fused" => Backend::CpuFused,
            other => anyhow::bail!("unknown serve backend {other:?} (want \"pjrt\" or \"cpu\")"),
        };
        let policy = BatchPolicy {
            max_batch: cfg.max_batch,
            max_wait: Duration::from_micros(cfg.max_wait_us),
            queue_cap: cfg.queue_cap,
            eager_idle: cfg.eager_idle,
        };
        // Executor sizing: `workers == 0` means auto — derived from the
        // shared pool, since every executor fans its CPU work (scan
        // plane/segment jobs, batch assembly) into ThreadPool::global();
        // more than ~half the pool width of executors just queues behind
        // the pool without improving throughput.
        let n_workers = if cfg.workers == 0 {
            (ThreadPool::global().threads() / 2).clamp(1, 8)
        } else {
            cfg.workers
        };
        let mut batcher = Batcher::new(policy);
        match backend {
            Backend::Pjrt => {
                // Group scan artifacts into buckets with their batch sizes.
                let manifest = Manifest::load(&cfg.artifacts)?;
                let mut sizes: std::collections::BTreeMap<Bucket, Vec<usize>> =
                    Default::default();
                for e in manifest.by_kind("scan") {
                    let bucket = Bucket {
                        c: e.meta_usize("c").unwrap_or(0),
                        h: e.meta_usize("h").unwrap_or(0),
                        w: e.meta_usize("w").unwrap_or(0),
                        kchunk: e.meta_usize("kchunk").unwrap_or(0),
                        per_channel: e.meta_usize("cw").unwrap_or(1) > 1,
                    };
                    sizes.entry(bucket).or_default().push(e.meta_usize("n").unwrap_or(1));
                }
                let n_buckets = sizes.len();
                for (b, s) in sizes {
                    batcher.register_bucket(b, s);
                }
                logging::info(
                    "coordinator",
                    &format!("{} scan buckets, {} workers (pjrt)", n_buckets, n_workers),
                );
            }
            Backend::CpuFused => {
                // The fused CPU engine serves any valid geometry at any
                // batch size; buckets register on first submit.
                logging::info(
                    "coordinator",
                    &format!("cpu-fused backend, {} workers", n_workers),
                );
            }
        }

        let shared = Arc::new(Shared {
            batcher: Mutex::new(batcher),
            direct: Mutex::new(VecDeque::new()),
            work_ready: Condvar::new(),
            metrics: Mutex::new(Metrics::with_slo(cfg.slo_p99_us.saturating_mul(1_000))),
            shutdown: AtomicBool::new(false),
            artifacts_dir: cfg.artifacts.clone(),
            backend,
            slo: SloPolicy::from_cfg(cfg),
            quotas: Mutex::new(QuotaState::new(cfg.quota_rps, cfg.quota_burst)),
            workspace: Arc::new(BufferPool::new(cfg.workspace_cap_mb << 20)),
            workspace_prewarm: cfg.workspace_prewarm,
            max_request_bytes: cfg.max_request_mb << 20,
        });
        let workers = (0..n_workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gspn2-exec-{i}"))
                    .spawn(move || worker_main(i, sh))
                    .expect("spawn worker")
            })
            .collect();
        Ok(Coordinator { shared, workers, next_id: AtomicU64::new(1) })
    }

    /// Number of executor worker threads actually running (resolves the
    /// `workers = 0` auto sizing).
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Submit one single-sample scan with default options (normal
    /// priority, no deadline beyond the class SLO budget, tenant 0);
    /// returns the response channel.
    pub fn submit_scan(
        &self,
        x: Tensor,
        a_raw: Tensor,
        lam: Tensor,
        kchunk: usize,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_scan_with(x, a_raw, lam, kchunk, SubmitOptions::default())
    }

    /// True when the coordinator should start refusing sheddable
    /// traffic: queue depth sits at/above the shed watermark, or the
    /// rolling error budget (fraction of recent completions violating
    /// the p99 SLO) is overspent. Both locks are taken briefly and
    /// never nested.
    fn overloaded(&self) -> bool {
        if self.shared.slo.shed_depth > 0
            && lock_unpoisoned(&self.shared.batcher).queued() >= self.shared.slo.shed_depth
        {
            return true;
        }
        self.shared.slo.p99_set
            && lock_unpoisoned(&self.shared.metrics).error_budget()
                > self.shared.slo.error_budget
    }

    /// Submit one single-sample scan with explicit priority, deadline,
    /// and tenant. Admission order: shutdown gate, shape validation,
    /// per-tenant quota, overload shedding (low priority only), then
    /// the bucket/backpressure checks. Every refusal is a structured
    /// [`SubmitError`] and a typed rejection counter — never a hang.
    pub fn submit_scan_with(
        &self,
        x: Tensor,
        a_raw: Tensor,
        lam: Tensor,
        kchunk: usize,
        opts: SubmitOptions,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::Closed);
        }
        // Admission validation: reject malformed geometry with a
        // structured error here rather than panicking a worker later
        // (e.g. scan_l2r's kchunk-divides-W assert).
        if let Err(why) = validate_scan_shapes(&x, &a_raw, &lam, kchunk) {
            lock_unpoisoned(&self.shared.metrics).record_invalid();
            return Err(SubmitError::Invalid(why));
        }
        let now = Instant::now();
        if !lock_unpoisoned(&self.shared.quotas).admit(opts.tenant, now) {
            lock_unpoisoned(&self.shared.metrics).record_quota();
            return Err(SubmitError::Quota(opts.tenant));
        }
        // Only the low class sheds — high/normal keep their latency
        // budget through overload and are bounded only by the hard
        // queue_cap backpressure below.
        if opts.priority == Priority::Low && self.overloaded() {
            lock_unpoisoned(&self.shared.metrics).record_shed(Priority::Low);
            return Err(SubmitError::Shed);
        }
        let deadline = opts
            .deadline
            .or_else(|| self.shared.slo.class_budget(opts.priority))
            .map(|budget| now + budget);
        let payload = Payload::Scan { x, a_raw, lam };
        let bucket = payload.bucket(kchunk).expect("scan payload");
        // Per-request workspace admission cap (`serve.max_request_mb`):
        // price the request the way the executor will actually run it —
        // tiling included, so an over-cap geometry that the engine can
        // stream in row bands is admitted at its bounded per-band
        // footprint. Only a demand tiling cannot bound is refused, and
        // by a structured *reply* (like Deadline/Closed) rather than a
        // submit error: the caller holds a normal receiver and learns
        // the cap from the typed [`RequestError::TooLarge`]. Crucially
        // this runs before bucket registration and pre-warm, so an
        // oversized geometry never fills free lists past the pool cap.
        if self.shared.max_request_bytes > 0 {
            let need = self.planned_request_bytes(&bucket);
            if need > self.shared.max_request_bytes as u64 {
                lock_unpoisoned(&self.shared.metrics).record_too_large();
                let (tx, rx) = mpsc::channel();
                let _ = tx.send(Response {
                    id: self.next_id.fetch_add(1, Ordering::Relaxed),
                    result: Err(anyhow::Error::new(RequestError::TooLarge {
                        need_mb: need.div_ceil(1 << 20),
                        cap_mb: (self.shared.max_request_bytes >> 20) as u64,
                    })),
                    queue_us: 0,
                    execute_us: 0,
                    batch: 0,
                });
                return Ok(rx);
            }
        }
        let (tx, rx) = mpsc::channel();
        let mut newly_registered = false;
        {
            let mut b = lock_unpoisoned(&self.shared.batcher);
            let known = b.known_bucket(&bucket);
            if !known && self.shared.backend != Backend::CpuFused {
                lock_unpoisoned(&self.shared.metrics).record_invalid();
                return Err(SubmitError::UnknownBucket(bucket.artifact(1)));
            }
            if !b.has_capacity() {
                lock_unpoisoned(&self.shared.metrics).record_backpressure();
                return Err(SubmitError::Backpressure);
            }
            if !known {
                // The fused CPU engine serves any valid geometry at any
                // batch size: register the bucket on first use (admission
                // already validated the shapes, and the backpressure
                // check above ran first so a rejected request never
                // burns a registration). The count is capped so a client
                // cycling through geometries cannot grow batcher state —
                // and pop_batch's key scan — without bound; beyond the
                // cap, novel geometries get the same structured
                // rejection the pjrt backend gives.
                // The cap measures *live* registrations: the batcher
                // prunes a dynamic bucket once its queue drains, so
                // steady traffic over shifting geometries recycles slots
                // instead of exhausting them.
                const MAX_DYNAMIC_BUCKETS: usize = 1024;
                if b.bucket_count() >= MAX_DYNAMIC_BUCKETS {
                    lock_unpoisoned(&self.shared.metrics).record_invalid();
                    return Err(SubmitError::UnknownBucket(bucket.artifact(1)));
                }
                let max = b.policy.max_batch.max(1);
                b.register_bucket_dynamic(bucket.clone(), (1..=max).collect());
                newly_registered = true;
            }
            let req = Request {
                id: self.next_id.fetch_add(1, Ordering::Relaxed),
                payload,
                kchunk,
                arrived: now,
                priority: opts.priority,
                deadline,
                tenant: opts.tenant,
                reply: tx,
            };
            if b.enqueue(bucket.clone(), req).is_err() {
                // Unreachable while the known_bucket check above holds
                // (same lock), but the batcher no longer auto-creates
                // queues — surface it as the structured rejection.
                lock_unpoisoned(&self.shared.metrics).record_invalid();
                return Err(SubmitError::UnknownBucket(bucket.artifact(1)));
            }
        }
        // Pre-warm outside the batcher lock: pricing the plan and
        // filling free lists must not stall concurrent submitters.
        if newly_registered && self.shared.workspace_prewarm {
            self.prewarm_bucket(&bucket);
        }
        self.shared.work_ready.notify_one();
        Ok(rx)
    }

    /// Resolve the execution plan the cpu-fused path will actually run
    /// for `bucket`'s geometry: the planner's decision, wrapped by the
    /// same bounded-memory tiling guard ([`maybe_tile`]) the engine
    /// applies against this coordinator's workspace cap. Keeping
    /// admission, pre-warm, and execution on one pricing path is what
    /// makes the `TooLarge` guard and the warm-bucket zero-miss
    /// invariant agree with what the workers lease.
    fn planned_bucket(&self, bucket: &Bucket) -> (ScanGeometry, ScanPlan, usize) {
        let pool = ThreadPool::global();
        let geom = ScanGeometry::single_dir(bucket.c.max(1), bucket.h, bucket.w);
        let tap_blocks = if bucket.per_channel { bucket.c.max(1) } else { 1 };
        let plan = plan_scan(&geom, 0, pool.threads());
        let plan = maybe_tile(
            plan,
            &geom,
            pool.threads(),
            tap_blocks,
            self.shared.workspace.cap_bytes(),
            crate::scan::simd::precision() == crate::scan::simd::Precision::Bf16,
        );
        (geom, plan, tap_blocks)
    }

    /// Planned peak workspace demand for one n=1 request of `bucket`,
    /// in bytes — the scratch classes from [`workspace_footprint`] for
    /// the resolved (possibly tiled) plan. This is the quantity the
    /// `serve.max_request_mb` admission cap compares against.
    fn planned_request_bytes(&self, bucket: &Bucket) -> u64 {
        let pool = ThreadPool::global();
        let (geom, plan, tap_blocks) = self.planned_bucket(bucket);
        workspace_footprint(&geom, plan.strategy, pool.threads(), tap_blocks)
            .into_iter()
            .map(|(len, count)| (len * count * 4) as u64)
            .sum()
    }

    /// Fill the workspace free lists with the scratch the cpu-fused
    /// path will lease for `bucket`, priced by the planner's
    /// [`workspace_footprint`] model, so the bucket's very first
    /// request is already allocation-free. Pre-warming counts neither
    /// as hits nor misses and respects the pool's retention cap.
    fn prewarm_bucket(&self, bucket: &Bucket) {
        let pool = ThreadPool::global();
        let (geom, plan, tap_blocks) = self.planned_bucket(bucket);
        for (len, count) in
            workspace_footprint(&geom, plan.strategy, pool.threads(), tap_blocks)
        {
            self.shared.workspace.prewarm(len, count);
        }
        // The reply tensor's class too (one n=1 request's output): the
        // output buffer is taken from this pool and donated back by the
        // client's ReplyLease drop, so the footprint model's scratch
        // classes alone don't cover it.
        self.shared.workspace.prewarm(geom.nplanes * geom.plane_px, 1);
    }

    /// Snapshot of the coordinator's workspace pool counters — the
    /// observable behind the allocation-free serving invariant (a warm
    /// bucket's repeat request must add zero misses).
    pub fn workspace_stats(&self) -> PoolStats {
        self.shared.workspace.stats()
    }

    /// Submit a direct whole-artifact execution (not batched).
    pub fn submit_direct(
        &self,
        artifact: &str,
        inputs: Vec<Value>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::Closed);
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut q = lock_unpoisoned(&self.shared.direct);
            if q.len() >= 64 {
                lock_unpoisoned(&self.shared.metrics).record_backpressure();
                return Err(SubmitError::Backpressure);
            }
            q.push_back(Request {
                id: self.next_id.fetch_add(1, Ordering::Relaxed),
                payload: Payload::Direct { artifact: artifact.to_string(), inputs },
                kchunk: 0,
                arrived: Instant::now(),
                priority: Priority::default(),
                deadline: None,
                tenant: 0,
                reply: tx,
            });
        }
        self.shared.work_ready.notify_one();
        Ok(rx)
    }

    pub fn metrics(&self) -> Metrics {
        lock_unpoisoned(&self.shared.metrics).clone()
    }

    pub fn queued(&self) -> usize {
        lock_unpoisoned(&self.shared.batcher).queued()
            + lock_unpoisoned(&self.shared.direct).len()
    }

    /// Graceful drain: stop admitting, process everything queued, join.
    /// Every request still pending after the workers exit — including
    /// any that raced past admission during the drain — resolves with a
    /// structured [`RequestError::Closed`] reply; no client ever hangs
    /// on a receiver across shutdown.
    pub fn shutdown(mut self) -> Metrics {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        close_pending(&self.shared);
        let m = lock_unpoisoned(&self.shared.metrics).clone();
        m
    }
}

/// Reply to a request with a structured typed error (downcastable from
/// the `anyhow::Error` via `err.downcast_ref::<RequestError>()`).
fn reply_request_error(r: &Request, err: RequestError) {
    let _ = r.reply.send(Response {
        id: r.id,
        result: Err(anyhow::Error::new(err)),
        queue_us: Instant::now().saturating_duration_since(r.arrived).as_micros() as u64,
        execute_us: 0,
        batch: 0,
    });
}

/// Resolve expired requests the batcher shed at pop time: counted per
/// class and answered with a `Deadline` reply — never executed dead.
fn shed_expired(sh: &Shared, reqs: Vec<Request>) {
    if reqs.is_empty() {
        return;
    }
    let mut m = lock_unpoisoned(&sh.metrics);
    for r in reqs {
        m.record_expired(r.priority);
        reply_request_error(&r, RequestError::Deadline);
    }
}

/// Final shutdown sweep: anything still queued (a submit that raced the
/// workers' last pop) gets a structured `Closed` reply so its receiver
/// resolves instead of hanging on a dropped-but-never-answered channel.
fn close_pending(sh: &Shared) {
    let mut leftovers: Vec<Request> = Vec::new();
    {
        let mut b = lock_unpoisoned(&sh.batcher);
        b.drain_all(|_, _, reqs| leftovers.extend(reqs));
        leftovers.extend(b.take_expired());
    }
    leftovers.extend(lock_unpoisoned(&sh.direct).drain(..));
    if leftovers.is_empty() {
        return;
    }
    let mut m = lock_unpoisoned(&sh.metrics);
    for r in &leftovers {
        m.record_closed();
        reply_request_error(r, RequestError::Closed);
    }
}

fn worker_main(idx: usize, sh: Arc<Shared>) {
    // The cpu-fused backend needs no PJRT engine (and must not require
    // an artifact directory to exist).
    let engine = match sh.backend {
        Backend::CpuFused => None,
        Backend::Pjrt => match Engine::cpu(&sh.artifacts_dir) {
            Ok(e) => Some(e),
            Err(e) => {
                logging::error("worker", &format!("worker {idx}: engine init failed: {e:#}"));
                return;
            }
        },
    };
    loop {
        // 1) Direct requests take priority (they are latency-sensitive
        //    whole-model calls).
        let direct = lock_unpoisoned(&sh.direct).pop_front();
        if let Some(req) = direct {
            match &engine {
                Some(engine) => run_direct(engine, &sh, req),
                None => reject_direct(&sh, req),
            }
            continue;
        }
        // 2) Batched scan work. Each clocked pop may also shed expired
        //    requests into the batcher's side-list; carry them out of
        //    the lock scope and answer them below.
        let (batch, expired) = {
            let mut b = lock_unpoisoned(&sh.batcher);
            loop {
                let now = Instant::now();
                let popped = b.pop_batch(now);
                let expired = b.take_expired();
                if popped.is_some() || !expired.is_empty() {
                    break (popped, expired);
                }
                // Direct work may have arrived while we waited; bounce out
                // to the outer loop (which prioritises it).
                if !lock_unpoisoned(&sh.direct).is_empty() {
                    break (None, Vec::new());
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    // Drain leftovers regardless of age (clock-free —
                    // the shifted-horizon emulation this used to do is
                    // the stale-instant pattern the batcher retired).
                    break (b.pop_eager(), Vec::new());
                }
                // Eager-idle release: this worker has nothing runnable, so
                // waiting out max_wait would buy batching nothing — take
                // the queue head now (only fires when queues are non-empty
                // but un-aged and un-full). Sized off the execution
                // plan's cost estimate, per bucket: one request's plan
                // says how wide its phase-1 fan is; if the pool's idle
                // capacity swallows that fan the release buys latency,
                // otherwise the batcher holds out for enough requests to
                // make the wait worthwhile — up to a full fused batch on
                // a fully busy pool (aged heads still release through
                // pop_batch above, bounding the delay by max_wait).
                if b.policy.eager_idle {
                    let pool = ThreadPool::global();
                    let (load, threads) = (pool.load(), pool.threads());
                    let max_batch = b.policy.max_batch;
                    let max_wait = b.policy.max_wait;
                    // Release sizing sees memory pressure too: with most
                    // of the workspace cap already on lease, extra
                    // concurrent scans would just churn the allocator.
                    // And deadline pressure: a head running out of SLO
                    // slack releases immediately instead of holding for
                    // a wider fuse.
                    let ws = sh.workspace.stats();
                    let ws_cap = sh.workspace.cap_bytes();
                    let released = b.pop_eager_by(|bucket, _qlen, head_deadline| {
                        let geom =
                            ScanGeometry::single_dir(bucket.c.max(1), bucket.h, bucket.w);
                        let plan = plan_scan(&geom, load, threads);
                        eager_release_min_slo(
                            &plan,
                            load,
                            threads,
                            max_batch,
                            ws.bytes_leased,
                            ws_cap,
                            head_deadline.map(|d| d.saturating_duration_since(now)),
                            max_wait,
                        )
                    });
                    if let Some(batch) = released {
                        break (Some(batch), Vec::new());
                    }
                }
                let timeout = b
                    .next_deadline()
                    .map(|d| d.saturating_duration_since(now))
                    .unwrap_or(Duration::from_millis(5));
                let (nb, _t) = sh
                    .work_ready
                    .wait_timeout(b, timeout.max(Duration::from_micros(100)))
                    .unwrap_or_else(|e| e.into_inner());
                b = nb;
            }
        };
        shed_expired(&sh, expired);
        match batch {
            Some((bucket, fused, reqs)) => match &engine {
                Some(engine) => run_scan_batch(engine, &sh, bucket, fused, reqs),
                None => run_scan_batch_cpu(&sh, &bucket, reqs),
            },
            None => {
                if sh.shutdown.load(Ordering::SeqCst)
                    && lock_unpoisoned(&sh.direct).is_empty()
                {
                    return;
                }
                // Otherwise: loop back to pick up direct work.
            }
        }
    }
}

fn run_direct(engine: &Engine, sh: &Shared, req: Request) {
    let t0 = Instant::now();
    let queue_ns = t0.saturating_duration_since(req.arrived).as_nanos() as u64;
    let class = req.priority;
    let (artifact, inputs) = match req.payload {
        Payload::Direct { artifact, inputs } => (artifact, inputs),
        _ => unreachable!("direct queue holds direct payloads"),
    };
    let result = engine.run(&artifact, &inputs);
    let exec_ns = t0.elapsed().as_nanos() as u64;
    let ok = result.is_ok();
    let _ = req.reply.send(Response {
        id: req.id,
        // PJRT output buffers did not come from the workspace pool:
        // an unpooled lease drops them normally.
        result: result.map(ReplyLease::unpooled),
        queue_us: queue_ns / 1000,
        execute_us: exec_ns / 1000,
        batch: 1,
    });
    let mut m = lock_unpoisoned(&sh.metrics);
    if ok {
        m.record_request(class, None, queue_ns, exec_ns, queue_ns + exec_ns, 1);
    } else {
        m.record_error();
    }
}

/// Direct (whole-artifact) execution has no CPU fallback: reply with a
/// structured error instead of hanging the client.
fn reject_direct(sh: &Shared, req: Request) {
    lock_unpoisoned(&sh.metrics).record_error();
    let _ = req.reply.send(Response {
        id: req.id,
        result: Err(anyhow!("direct execution requires the pjrt backend")),
        queue_us: req.arrived.elapsed().as_micros() as u64,
        execute_us: 0,
        batch: 1,
    });
}

/// Serve a scan batch on the fused CPU engine: per request, normalize
/// the raw taps and run the column-staged fused scan with its plane
/// blocks fanned out on the process-wide pool. No concat/pad/split —
/// the CPU path has no shape-specialised executable to feed, so each
/// request's tensors are consumed in place. The execution planner
/// ([`crate::scan::plan::plan_scan`]) covers both serving regimes:
/// many-plane requests run plane-parallel, bit-identical to `scan_l2r`
/// (the e2e tests pin this with exact equality); a single
/// large-resolution request — too few planes to occupy the pool — runs
/// segment-parallel with wavefront continuations, bit-identical to
/// `scan_l2r_split` at the planned count (also e2e-pinned).
///
/// All engine scratch leases from the coordinator's workspace
/// ([`Shared::workspace`]) — and so does the reply tensor itself: its
/// buffer is taken from the pool ([`BufferPool::take_zeroed`]), written
/// in place by the engine, and donated back when the client drops the
/// [`ReplyLease`] it receives, so after one warm-up request per bucket
/// the hot path performs no heap allocation at all. Pool counters are
/// snapshotted into [`Metrics`] once per batch.
fn run_scan_batch_cpu(sh: &Shared, bucket: &Bucket, reqs: Vec<Request>) {
    let batch = reqs.len();
    for r in reqs {
        let t0 = Instant::now();
        // Belt and braces: a request whose deadline lapsed between
        // release and execution (e.g. while earlier batch members ran)
        // is answered with the structured Deadline reply, not executed
        // dead.
        if r.expired(t0) {
            lock_unpoisoned(&sh.metrics).record_expired(r.priority);
            reply_request_error(&r, RequestError::Deadline);
            continue;
        }
        let class = r.priority;
        let (x, a_raw, lam) = match r.payload {
            Payload::Scan { x, a_raw, lam } => (x, a_raw, lam),
            _ => unreachable!("scan batch holds scan payloads"),
        };
        // One panicking execution must cost exactly its own request: the
        // client gets a structured error response (not a dropped
        // channel), the error is counted, and the worker thread — and
        // with it every queued and future request — survives. Without
        // the catch, a panic here unwound the executor, leaked every
        // reply channel in the batch, and left later requests to queue
        // forever against a dead worker.
        // Per-request peak-workspace window: rebase the pool's
        // high-water mark here so the matching rebase after the run
        // reads this execution's own peak — the observable behind the
        // bounded-memory claim of the tiled streaming path (a tiled
        // over-cap request must peak at one band, not the full frame).
        // Approximate when other pool users overlap the window; see
        // [`BufferPool::rebase_peak`].
        sh.workspace.rebase_peak();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            #[cfg(test)]
            test_hooks::maybe_fail_scan(x.shape[1], x.shape[2], x.shape[3]);
            let taps = crate::scan::Taps::normalize(&a_raw);
            // Output buffer from the pool: a panic between here and the
            // reply just frees it (take transfers ownership; no gauge
            // to unwind).
            let out_buf = sh.workspace.take_zeroed(x.data.len());
            crate::scan::fused::fused_scan_l2r_pool_ws_into(
                &x,
                &taps,
                &lam,
                r.kchunk,
                ThreadPool::global(),
                &sh.workspace,
                out_buf,
            )
        }));
        let req_peak = sh.workspace.rebase_peak();
        let exec_ns = t0.elapsed().as_nanos() as u64;
        let queue_ns = t0.saturating_duration_since(r.arrived).as_nanos() as u64;
        match result {
            Ok(h) => {
                let _ = r.reply.send(Response {
                    id: r.id,
                    result: Ok(ReplyLease::new(
                        vec![Value::F32(h)],
                        Arc::downgrade(&sh.workspace),
                    )),
                    queue_us: queue_ns / 1000,
                    execute_us: exec_ns / 1000,
                    batch,
                });
                let mut m = lock_unpoisoned(&sh.metrics);
                m.record_request(class, Some(bucket), queue_ns, exec_ns, queue_ns + exec_ns, batch);
                m.record_request_ws_peak(req_peak);
            }
            Err(payload) => {
                let msg = crate::util::panic_message(&*payload);
                logging::error("worker", &format!("scan execution panicked: {msg}"));
                lock_unpoisoned(&sh.metrics).record_error();
                let _ = r.reply.send(Response {
                    id: r.id,
                    result: Err(anyhow!("scan execution panicked: {msg}")),
                    queue_us: queue_ns / 1000,
                    execute_us: exec_ns / 1000,
                    batch,
                });
            }
        }
    }
    lock_unpoisoned(&sh.metrics).record_workspace(sh.workspace.stats());
}

/// Test-only fault injection: lets the failed-batch regression test
/// force the cpu scan execution of one specific (C, H, W) geometry to
/// panic (one-shot, keyed so concurrently running tests — which use
/// other geometries — can never consume or trip it).
#[cfg(test)]
pub(crate) mod test_hooks {
    use std::sync::Mutex;

    pub(crate) static FAIL_SCAN_FOR: Mutex<Option<(usize, usize, usize)>> = Mutex::new(None);

    pub(crate) fn maybe_fail_scan(c: usize, h: usize, w: usize) {
        let mut g = crate::util::lock_unpoisoned(&FAIL_SCAN_FOR);
        if *g == Some((c, h, w)) {
            *g = None;
            drop(g);
            panic!("injected scan execution failure");
        }
    }
}

fn run_scan_batch(
    engine: &Engine,
    sh: &Shared,
    bucket: Bucket,
    fused: usize,
    reqs: Vec<Request>,
) {
    let t0 = Instant::now();
    let artifact = bucket.artifact(fused);
    // Shed anything that expired between release and execution before
    // assembling the fused inputs — a dead request must neither burn
    // executor time nor hang its client.
    let mut reqs = {
        let mut live = Vec::with_capacity(reqs.len());
        for r in reqs {
            if r.expired(t0) {
                lock_unpoisoned(&sh.metrics).record_expired(r.priority);
                reply_request_error(&r, RequestError::Deadline);
            } else {
                live.push(r);
            }
        }
        if live.is_empty() {
            return;
        }
        live
    };
    // Fast path: single request into a batch-1 artifact — move the
    // payload tensors straight into the input Values, no concat/split
    // copies (saves ~450 KB of memcpy per request at the 64^2 c8 bucket).
    if fused == 1 && reqs.len() == 1 {
        let r = reqs.pop().unwrap();
        let class = r.priority;
        let (x, a_raw, lam) = match r.payload {
            Payload::Scan { x, a_raw, lam } => (x, a_raw, lam),
            _ => unreachable!("scan batch holds scan payloads"),
        };
        let inputs = vec![Value::F32(x), Value::F32(a_raw), Value::F32(lam)];
        let result = engine.run(&artifact, &inputs);
        let exec_ns = t0.elapsed().as_nanos() as u64;
        let queue_ns = t0.saturating_duration_since(r.arrived).as_nanos() as u64;
        let ok = result.is_ok();
        let _ = r.reply.send(Response {
            id: r.id,
            result: result.map(ReplyLease::unpooled),
            queue_us: queue_ns / 1000,
            execute_us: exec_ns / 1000,
            batch: 1,
        });
        let mut m = lock_unpoisoned(&sh.metrics);
        if ok {
            m.record_request(class, Some(&bucket), queue_ns, exec_ns, queue_ns + exec_ns, 1);
        } else {
            m.record_error();
        }
        return;
    }
    // Assemble batch inputs (pad by repeating the first sample if the
    // smallest compiled batch exceeds the queue remainder).
    let mut xs: Vec<&Tensor> = Vec::with_capacity(fused);
    let mut avs: Vec<&Tensor> = Vec::with_capacity(fused);
    let mut lams: Vec<&Tensor> = Vec::with_capacity(fused);
    for r in &reqs {
        if let Payload::Scan { x, a_raw, lam } = &r.payload {
            xs.push(x);
            avs.push(a_raw);
            lams.push(lam);
        }
    }
    let pad = fused.saturating_sub(xs.len());
    for _ in 0..pad {
        xs.push(xs[0]);
        avs.push(avs[0]);
        lams.push(lams[0]);
    }
    if pad > 0 {
        lock_unpoisoned(&sh.metrics).record_padding(pad);
    }
    // Intra-batch parallelism on the shared pool: the three fused input
    // concats are independent memcpy-bound jobs (~hundreds of KB each at
    // the 64^2 c8 bucket), and the executor threads must not spawn
    // ad-hoc threads for them. Small batches concat inline instead —
    // the pool dispatch costs more than a short memcpy. (The pool's
    // helping wait only ever runs this call's own jobs, so the executor
    // cannot be stalled by a stranger's queued work either way.)
    const POOL_CONCAT_MIN_ELEMS: usize = 1 << 16;
    let fused_elems: usize = xs
        .iter()
        .chain(avs.iter())
        .chain(lams.iter())
        .map(|t| t.len())
        .sum();
    let inputs = if fused_elems < POOL_CONCAT_MIN_ELEMS {
        vec![
            Value::F32(concat_axis0(&xs)),
            Value::F32(concat_axis0(&avs)),
            Value::F32(concat_axis0(&lams)),
        ]
    } else {
        let groups: Vec<&[&Tensor]> = vec![&xs, &avs, &lams];
        let mut fusedt = ThreadPool::global().map(groups, concat_axis0);
        let lam_in = fusedt.pop().expect("three fused inputs");
        let av_in = fusedt.pop().expect("three fused inputs");
        let x_in = fusedt.pop().expect("three fused inputs");
        vec![Value::F32(x_in), Value::F32(av_in), Value::F32(lam_in)]
    };

    let result = engine.run(&artifact, &inputs);
    let exec_ns = t0.elapsed().as_nanos() as u64;

    match result {
        Ok(mut outs) => {
            let h = outs.remove(0).into_f32().expect("scan output is f32");
            let sizes = vec![1usize; fused];
            let mut parts = split_axis0(&h, &sizes);
            parts.truncate(reqs.len());
            let mut m = lock_unpoisoned(&sh.metrics);
            for (r, out) in reqs.iter().zip(parts.drain(..)) {
                let queue_ns = t0.saturating_duration_since(r.arrived).as_nanos() as u64;
                m.record_request(
                    r.priority,
                    Some(&bucket),
                    queue_ns,
                    exec_ns,
                    queue_ns + exec_ns,
                    fused,
                );
                let _ = r.reply.send(Response {
                    id: r.id,
                    result: Ok(ReplyLease::unpooled(vec![Value::F32(out)])),
                    queue_us: queue_ns / 1000,
                    execute_us: exec_ns / 1000,
                    batch: fused,
                });
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let mut m = lock_unpoisoned(&sh.metrics);
            for r in &reqs {
                m.record_error();
                let _ = r.reply.send(Response {
                    id: r.id,
                    result: Err(anyhow!("{msg}")),
                    queue_us: 0,
                    execute_us: exec_ns / 1000,
                    batch: fused,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cpu_cfg(workers: usize) -> ServeConfig {
        ServeConfig {
            backend: "cpu".into(),
            workers,
            max_batch: 4,
            max_wait_us: 200,
            queue_cap: 64,
            ..ServeConfig::default()
        }
    }

    fn mk_case(rng: &mut Rng, c: usize, h: usize, w: usize) -> (Tensor, Tensor, Tensor) {
        (
            Tensor::randn(&[1, c, h, w], rng, 1.0),
            Tensor::randn(&[1, 1, 3, h, w], rng, 1.0),
            Tensor::randn(&[1, c, h, w], rng, 1.0),
        )
    }

    /// The failed-batch regression: one panicking scan execution must
    /// come back as a structured error response (error counted in
    /// metrics), and the server — same worker, same metrics mutex —
    /// must keep serving later requests instead of dying poisoned.
    #[test]
    fn serving_survives_one_failed_batch() {
        use std::time::Duration;
        let coord = Coordinator::start(&cpu_cfg(1)).unwrap();
        let mut rng = Rng::new(90);
        // A geometry no other test submits, so the keyed hook can only
        // fire for this request even with suites running in parallel.
        let (x, a, lam) = mk_case(&mut rng, 3, 7, 11);
        *lock_unpoisoned(&test_hooks::FAIL_SCAN_FOR) = Some((3, 7, 11));
        let rx = coord.submit_scan(x, a, lam, 0).expect("submit");
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("worker must reply");
        let err = resp.result.expect_err("injected failure must surface as an error");
        assert!(
            format!("{err:#}").contains("injected scan execution failure"),
            "{err:#}"
        );
        // The same (only) worker serves the next request correctly.
        let (x, a, lam) = mk_case(&mut rng, 2, 8, 8);
        let want = crate::scan::scan_l2r(&x, &crate::scan::Taps::normalize(&a), &lam, 0);
        let rx = coord.submit_scan(x, a, lam, 0).expect("submit after failure");
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("server survived");
        let got = resp.result.expect("second request succeeds");
        assert_eq!(got[0].as_f32().unwrap().data, want.data);
        let m = coord.shutdown();
        assert_eq!(m.errors, 1, "the failed execution must be counted");
        assert_eq!(m.completed, 1);
    }

    /// The allocation-free serving invariant, end to end: after one
    /// warm-up request, a repeated identical request leases every
    /// scratch buffer from the coordinator's workspace — zero new pool
    /// misses, and nothing left on lease between requests. The reply
    /// tensor is covered too: its buffer is taken from the same pool
    /// (`take_zeroed` counts the same hit/miss ledger) and comes back
    /// when the client drops the `ReplyLease`, so the zero-miss
    /// assertion proves the *whole request* — reply included — runs
    /// allocation-free once warm.
    #[test]
    fn warm_bucket_repeat_request_records_zero_misses() {
        use std::time::Duration;
        let coord = Coordinator::start(&cpu_cfg(1)).unwrap();
        let mut rng = Rng::new(92);
        // Unique geometry; nplanes = c = 1 with a narrow plane keeps the
        // engine on its serial plane-parallel branch, so the lease
        // pattern is deterministic across runs.
        let (x, a, lam) = mk_case(&mut rng, 1, 9, 13);
        let want = crate::scan::scan_l2r(&x, &crate::scan::Taps::normalize(&a), &lam, 0);
        let rx = coord.submit_scan(x.clone(), a.clone(), lam.clone(), 0).expect("submit");
        let got =
            rx.recv_timeout(Duration::from_secs(120)).expect("reply").result.expect("ok");
        assert_eq!(got[0].as_f32().unwrap().data, want.data);
        // Dropping the reply lease donates the reply buffer back to the
        // coordinator's pool — the client half of the recycling loop.
        drop(got);
        let s1 = coord.workspace_stats();
        assert_eq!(s1.bytes_leased, 0, "all leases must return between requests");
        let rx = coord.submit_scan(x, a, lam, 0).expect("submit warm");
        let got =
            rx.recv_timeout(Duration::from_secs(120)).expect("reply").result.expect("ok");
        assert_eq!(got[0].as_f32().unwrap().data, want.data);
        drop(got);
        let s2 = coord.workspace_stats();
        assert_eq!(
            s2.misses, s1.misses,
            "warm bucket repeat must add zero pool misses (reply take included)"
        );
        assert!(s2.hits > s1.hits, "warm pass must serve from the pool");
        let m = coord.shutdown();
        assert_eq!(m.ws_misses, s2.misses, "metrics must surface the pool counters");
    }

    /// Workspace integrity across a panicking execution: the injected
    /// failure must leave zero bytes on lease, and a bucket that was
    /// already warm stays allocation-free afterwards.
    #[test]
    fn panicking_request_leaks_no_workspace_leases() {
        use std::time::Duration;
        let coord = Coordinator::start(&cpu_cfg(1)).unwrap();
        let mut rng = Rng::new(93);
        // Warm one bucket (unique geometry).
        let (x, a, lam) = mk_case(&mut rng, 1, 10, 14);
        let rx = coord.submit_scan(x.clone(), a.clone(), lam.clone(), 0).expect("submit");
        rx.recv_timeout(Duration::from_secs(120)).expect("reply").result.expect("ok");
        let warm = coord.workspace_stats();
        assert_eq!(warm.bytes_leased, 0);
        // Panic a different geometry's execution (keyed one-shot hook).
        let (px, pa, plam) = mk_case(&mut rng, 5, 7, 13);
        *lock_unpoisoned(&test_hooks::FAIL_SCAN_FOR) = Some((5, 7, 13));
        let rx = coord.submit_scan(px, pa, plam, 0).expect("submit");
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("reply");
        resp.result.expect_err("injected failure must surface as an error");
        let s = coord.workspace_stats();
        assert_eq!(s.bytes_leased, 0, "a panicking execution must not leak leases");
        // The warm bucket still serves miss-free.
        let rx = coord.submit_scan(x, a, lam, 0).expect("submit warm");
        rx.recv_timeout(Duration::from_secs(120)).expect("reply").result.expect("ok");
        let s2 = coord.workspace_stats();
        assert_eq!(s2.misses, warm.misses, "warm bucket must stay miss-free after a panic");
        assert_eq!(s2.bytes_leased, 0);
        coord.shutdown();
    }

    /// The shutdown sweep: requests still queued after the workers are
    /// gone (the submit-races-final-pop window) must resolve with a
    /// structured `Closed` reply — no receiver may hang. Exercised
    /// race-free against a hand-built `Shared` with no workers at all.
    #[test]
    fn close_pending_resolves_queued_with_closed() {
        use std::time::Duration;
        let mut rng = Rng::new(94);
        let (x, a_raw, lam) = mk_case(&mut rng, 2, 5, 9);
        let payload = Payload::Scan { x, a_raw, lam };
        let bucket = payload.bucket(0).unwrap();
        let mut batcher = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            queue_cap: 64,
            eager_idle: false,
        });
        batcher.register_bucket(bucket.clone(), vec![1]);
        let sh = Shared {
            batcher: Mutex::new(batcher),
            direct: Mutex::new(VecDeque::new()),
            work_ready: Condvar::new(),
            metrics: Mutex::new(Metrics::new()),
            shutdown: AtomicBool::new(true),
            artifacts_dir: String::new(),
            backend: Backend::CpuFused,
            slo: SloPolicy::from_cfg(&ServeConfig::default()),
            quotas: Mutex::new(QuotaState::new(0.0, 1)),
            workspace: Arc::new(BufferPool::new(1 << 20)),
            workspace_prewarm: false,
            max_request_bytes: 0,
        };
        let (tx, rx_scan) = mpsc::channel();
        let req = Request {
            id: 1,
            payload,
            kchunk: 0,
            arrived: Instant::now(),
            priority: Priority::Low,
            deadline: None,
            tenant: 0,
            reply: tx,
        };
        lock_unpoisoned(&sh.batcher).enqueue(bucket, req).unwrap();
        let (tx, rx_direct) = mpsc::channel();
        lock_unpoisoned(&sh.direct).push_back(Request {
            id: 2,
            payload: Payload::Direct { artifact: "m".into(), inputs: vec![] },
            kchunk: 0,
            arrived: Instant::now(),
            priority: Priority::High,
            deadline: None,
            tenant: 0,
            reply: tx,
        });
        close_pending(&sh);
        for rx in [rx_scan, rx_direct] {
            let resp = rx.recv_timeout(Duration::from_secs(5)).expect("sweep must reply");
            let err = resp.result.expect_err("closed, not executed");
            assert_eq!(err.downcast_ref::<RequestError>(), Some(&RequestError::Closed));
        }
        let m = lock_unpoisoned(&sh.metrics);
        assert_eq!(m.closed, 2);
        assert_eq!(m.rejected, 0, "closed requests are not rejections");
        assert_eq!(lock_unpoisoned(&sh.batcher).queued(), 0);
    }

    /// An already-dead deadline still gets admitted (the queue had
    /// room) but must come back as a structured `Deadline` reply
    /// without ever executing.
    #[test]
    fn deadline_zero_request_gets_structured_deadline_reply() {
        use std::time::Duration;
        let coord = Coordinator::start(&cpu_cfg(1)).unwrap();
        let mut rng = Rng::new(95);
        let (x, a, lam) = mk_case(&mut rng, 2, 6, 10);
        let opts = SubmitOptions { deadline: Some(Duration::ZERO), ..Default::default() };
        let rx = coord.submit_scan_with(x, a, lam, 0, opts).expect("admitted");
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("must resolve");
        let err = resp.result.expect_err("expired before execution");
        assert_eq!(err.downcast_ref::<RequestError>(), Some(&RequestError::Deadline));
        let m = coord.shutdown();
        assert_eq!(m.class_expired[Priority::Normal.index()], 1);
        assert_eq!(m.completed, 0, "a dead request must never execute");
    }

    /// Metrics reads recover from a poisoned mutex instead of
    /// propagating PoisonError to every later caller.
    #[test]
    fn metrics_lock_recovers_from_poison() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let coord = Coordinator::start(&cpu_cfg(1)).unwrap();
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = coord.shared.metrics.lock().unwrap();
            panic!("poison the metrics lock");
        }));
        assert!(coord.shared.metrics.is_poisoned());
        // metrics() and a full request round-trip still work.
        let m = coord.metrics();
        assert_eq!(m.completed, 0);
        let mut rng = Rng::new(91);
        let (x, a, lam) = mk_case(&mut rng, 1, 6, 6);
        let rx = coord.submit_scan(x, a, lam, 0).expect("submit");
        assert!(rx
            .recv_timeout(std::time::Duration::from_secs(120))
            .expect("reply")
            .result
            .is_ok());
        coord.shutdown();
    }

    /// The per-request admission cap with tiling disabled (workspace
    /// cap 0, so [`maybe_tile`] is a no-op): a geometry whose planned
    /// demand exceeds `max_request_mb` must come back as a structured
    /// `TooLarge` *reply* naming the cap — counted as a rejection,
    /// never queued, never pre-warmed — and the coordinator must keep
    /// serving in-cap traffic afterwards.
    #[test]
    fn oversize_request_gets_structured_too_large_reply() {
        use std::time::Duration;
        let cfg = ServeConfig {
            max_request_mb: 1,
            workspace_cap_mb: 0,
            workspace_prewarm: false,
            ..cpu_cfg(1)
        };
        let coord = Coordinator::start(&cfg).unwrap();
        let mut rng = Rng::new(96);
        // 128x1024 single-plane: the staged tap panels alone price at
        // 3*128*1024 floats (2 MiB after class rounding) — over the
        // 1 MiB per-request cap, and untileable with workspace cap 0.
        let (x, a, lam) = mk_case(&mut rng, 1, 128, 1024);
        let rx = coord.submit_scan(x, a, lam, 0).expect("admission returns a receiver");
        let resp = rx.recv_timeout(Duration::from_secs(5)).expect("guard must reply");
        let err = resp.result.expect_err("over-cap demand must be refused");
        match err.downcast_ref::<RequestError>() {
            Some(RequestError::TooLarge { need_mb, cap_mb }) => {
                assert_eq!(*cap_mb, 1);
                assert!(*need_mb > *cap_mb, "priced demand must exceed the cap");
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // In-cap traffic still serves, bit-exact.
        let (x, a, lam) = mk_case(&mut rng, 1, 6, 12);
        let want = crate::scan::scan_l2r(&x, &crate::scan::Taps::normalize(&a), &lam, 0);
        let rx = coord.submit_scan(x, a, lam, 0).expect("submit small");
        let got = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("reply")
            .result
            .expect("in-cap request succeeds");
        assert_eq!(got[0].as_f32().unwrap().data, want.data);
        drop(got);
        let m = coord.shutdown();
        assert_eq!(m.rej_too_large, 1);
        assert_eq!(m.completed, 1);
        assert!(m.report().contains("1 too-large"), "{}", m.report());
    }

    /// Bounded-memory high-res serving, end to end: the same geometry
    /// the too-large test refuses is *admitted* once the workspace cap
    /// enables tiling — priced at its per-band footprint, executed as a
    /// row-band stream, bit-identical to the monolithic `scan_l2r`
    /// reference — and the per-request peak-workspace metric shows the
    /// peak stayed below the full-frame staging cost.
    #[test]
    fn overcap_geometry_streams_in_bands_within_budget() {
        use std::time::Duration;
        let cfg = ServeConfig {
            max_request_mb: 8,
            workspace_cap_mb: 1,
            ..cpu_cfg(1)
        };
        let coord = Coordinator::start(&cfg).unwrap();
        let mut rng = Rng::new(97);
        let (x, a, lam) = mk_case(&mut rng, 1, 128, 1024);
        let want = crate::scan::scan_l2r(&x, &crate::scan::Taps::normalize(&a), &lam, 0);
        let rx = coord.submit_scan(x, a, lam, 0).expect("tiling admits the geometry");
        let got = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("reply")
            .result
            .expect("tiled execution succeeds");
        assert_eq!(got[0].as_f32().unwrap().data, want.data, "banded == monolithic");
        drop(got);
        let m = coord.shutdown();
        assert_eq!(m.rej_too_large, 0, "tiling must admit, not reject");
        assert_eq!(m.completed, 1);
        // Full-frame staging alone is 3*128*1024 floats -> 2 MiB after
        // class rounding; a banded run must peak well under that.
        let untiled_staged_bytes = (3 * 128 * 1024 * 4) as f64;
        assert_eq!(m.ws_req_peak.count(), 1);
        assert!(m.ws_req_peak.max() > 0.0, "execution must lease workspace");
        assert!(
            m.ws_req_peak.max() < untiled_staged_bytes,
            "peak {} must stay below full-frame staging {}",
            m.ws_req_peak.max(),
            untiled_staged_bytes
        );
        assert!(m.report().contains("per-request peak workspace"), "{}", m.report());
    }
}
