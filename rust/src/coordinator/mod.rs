//! The L3 serving coordinator (the paper is pitched at high-resolution
//! inference, so L3 takes the vLLM-router shape; DESIGN.md §4):
//!
//! * [`request`] — request/response types, shape buckets, priority
//!   classes, deadlines, and structured per-request errors.
//! * [`batcher`] — the shape-bucketed dynamic batching policy (pure, so
//!   it is unit-tested and benched without PJRT); releases by earliest
//!   effective deadline and sheds expired requests at pop time.
//! * [`server`]  — SLO-aware admission control (per-tenant quotas,
//!   low-priority load shedding under overload) + worker pool driving
//!   PJRT engines, with a shutdown drain that resolves every pending
//!   request.
//! * [`metrics`] — latency histograms (aggregate, per-class, and
//!   per-bucket), typed rejection counters, rolling SLO error budget.
//! * [`trace`]   — synthetic load generator: open-loop Poisson, plus a
//!   Markov-modulated bursty mode for tail-latency benchmarking and a
//!   priority/tenant mix for overload experiments.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;
pub mod trace;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use request::{
    validate_scan_shapes, Bucket, Payload, Priority, ReplyLease, Request, RequestError,
    Response, SubmitError, SubmitOptions,
};
pub use server::Coordinator;
pub use trace::{
    generate as generate_trace, BurstConfig, ClassMix, TraceConfig, TraceEvent,
};
