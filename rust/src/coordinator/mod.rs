//! The L3 serving coordinator (the paper is pitched at high-resolution
//! inference, so L3 takes the vLLM-router shape; DESIGN.md §4):
//!
//! * [`request`] — request/response types and shape buckets.
//! * [`batcher`] — the shape-bucketed dynamic batching policy (pure, so
//!   it is unit-tested and benched without PJRT).
//! * [`server`]  — admission control + worker pool driving PJRT engines.
//! * [`metrics`] — latency histograms, throughput, batching stats.
//! * [`trace`]   — synthetic load generator: open-loop Poisson, plus a
//!   Markov-modulated bursty mode for tail-latency benchmarking.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;
pub mod trace;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use request::{validate_scan_shapes, Bucket, Payload, Request, Response, SubmitError};
pub use server::Coordinator;
pub use trace::{generate as generate_trace, BurstConfig, TraceConfig, TraceEvent};
