use gspn2::gpusim::*;
fn main() {
    let dev = DeviceSpec::a100_sxm4_80gb();
    for p in [&FIG3, &FIG_S3, &FIG_S4] {
        println!("== {} ==", p.label);
        for (s, paper) in p.run(&dev).iter().zip(p.paper_ms) {
            println!("  {:<26} {:>9.2} ms (paper {:>7.2})  step {:>5.2}x cum {:>6.1}x  eff {:.3} ach {:.0} GB/s ({:.1}%)",
                s.name, s.time_ms, paper, s.step_speedup, s.cum_speedup, s.sim.efficiency, s.sim.achieved_gbs, s.sim.pct_peak);
        }
    }
    println!("== Table 1 ==");
    for (n,c,h,w) in [(32,196,32,32),(1,768,64,64),(1,1152,64,64),(1,32,64,64),(1,32,128,128),(1,64,256,256),(8,64,256,256),(1,128,512,512)] {
        let wl = ScanWorkload::fwd(n,c,h,w);
        let g1 = simulate(&dev, &wl, &KernelConfig::gspn1());
        let g2 = simulate(&dev, &wl, &KernelConfig::gspn2());
        println!("  {:>4}x{:<4} b{:<3} c{:<4} G1 {:>6.0} GB/s ({:>4.1}%)  G2 {:>6.0} GB/s ({:>4.1}%)  t1={:.3}ms t2={:.4}ms",
            h, w, n, c, g1.achieved_gbs, g1.pct_peak, g2.achieved_gbs, g2.pct_peak, g1.time_ms, g2.time_ms);
    }
    println!("== speedup vs res (n4 c8) ==");
    for res in [128usize,256,512,1024,2048] {
        let wl = ScanWorkload::fwd(4,8,res,res);
        let s1 = simulate(&dev,&wl,&KernelConfig::gspn1());
        let s2 = simulate(&dev,&wl,&KernelConfig::gspn2());
        println!("  {res:>5}: g1 {:>9.3} ms  g2 {:>8.4} ms  speedup {:>6.1}x (g2: mem {:.3} lat {:.3} launch {:.3})", s1.time_ms, s2.time_ms, s1.time_ms/s2.time_ms, s2.mem_ms, s2.latency_ms, s2.launch_ms);
    }
    println!("== fig5 ==");
    let m = DiffusionModel::sdxl_like();
    for res in [1024usize, 2048, 4096, 8192, 16384] {
        let dense = m.generate_s(&dev,res,Backend::SdxlDense);
        let flash = m.generate_s(&dev,res,Backend::SdxlFlash);
        let g1 = m.generate_s(&dev,res,Backend::Gspn1);
        let g2 = m.generate_s(&dev,res,Backend::Gspn2);
        println!("  {res:>5}: dense {dense:>9.2}s flash {flash:>9.2}s g1 {g1:>8.2}s g2 {g2:>8.3}s  speedup(flash/g2) {:>6.1}x", flash/g2);
    }
    println!("== throughput (tiny) ==");
    for p in [2usize,4,8,16,32] {
        let arch = gspn2::model::GspnArch { c_proxy: p, ..gspn2::model::gspn2_tiny() };
        println!("  cproxy {p:>2}: {:>7.0} img/s", attention::classifier_throughput(&dev,&arch,224,64));
    }
}
