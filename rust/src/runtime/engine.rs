//! The PJRT execution engine: loads HLO-text artifacts, compiles them on
//! the CPU PJRT client, caches executables, and runs them with typed
//! host values.
//!
//! One `Engine` per OS thread: the underlying `xla` wrapper types hold
//! raw pointers and are not `Send`, so the coordinator gives each worker
//! thread its own engine (the PJRT CPU runtime itself multithreads the
//! compute internally).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{Entry, Manifest};
use super::value::Value;
use crate::util::logging;

pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative compile + execute statistics.
    pub stats: RefCell<EngineStats>,
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub compiles: usize,
    pub compile_ms: f64,
    pub executions: usize,
    pub execute_ms: f64,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn cpu(artifacts_dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        logging::debug(
            "engine",
            &format!(
                "PJRT client '{}' with {} device(s), {} artifacts",
                client.platform_name(),
                client.device_count(),
                manifest.entries.len()
            ),
        );
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.manifest.get(name)
    }

    /// Compile (or fetch from cache) the executable for an entry.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(exe));
        }
        let entry = self.manifest.get(name)?;
        let path = self.manifest.hlo_path(entry);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_ms += dt;
        }
        logging::debug("engine", &format!("compiled {name} in {dt:.1} ms"));
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Pre-compile a set of entries (warmup before serving).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    /// Execute an artifact with shape-checked inputs; returns the
    /// flattened outputs (the AOT pipeline lowers with return_tuple=True).
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let entry = self.manifest.get(name)?.clone();
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{name}: got {} inputs, manifest wants {}",
                inputs.len(),
                entry.inputs.len()
            );
        }
        for (v, spec) in inputs.iter().zip(&entry.inputs) {
            v.check(spec).with_context(|| format!("artifact {name}"))?;
        }
        let exe = self.load(name)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;

        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {name}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_ms += dt;
        }

        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "{name}: produced {} outputs, manifest says {}",
                parts.len(),
                entry.outputs.len()
            );
        }
        parts.iter().map(Value::from_literal).collect()
    }

    /// Load a model's initial parameters (params.bin) as values.
    pub fn initial_params(&self, entry_name: &str) -> Result<Vec<Value>> {
        let entry = self.manifest.get(entry_name)?;
        Ok(self
            .manifest
            .load_params(entry)?
            .into_iter()
            .map(Value::F32)
            .collect())
    }
}
