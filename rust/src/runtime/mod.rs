//! PJRT runtime: the L3 side of the AOT bridge.
//!
//! `python/compile/aot.py` lowers every L2 entrypoint to HLO *text*
//! (xla_extension 0.5.1 rejects serialized protos from jax >= 0.5 — see
//! DESIGN.md §4); this module loads those artifacts, compiles them once
//! per process on the PJRT CPU client, and executes them from the
//! serving / training hot paths with zero Python involvement.

pub mod engine;
pub mod manifest;
pub mod value;

pub use engine::{Engine, EngineStats};
pub use manifest::{artifacts_available, Dtype, Entry, IoSpec, Manifest};
pub use value::Value;
