//! PJRT runtime: the L3 side of the AOT bridge.
//!
//! `python/compile/aot.py` lowers every L2 entrypoint to HLO *text*
//! (xla_extension 0.5.1 rejects serialized protos from jax >= 0.5 — see
//! DESIGN.md §4); this module loads those artifacts, compiles them once
//! per process on the PJRT CPU client, and executes them from the
//! serving / training hot paths with zero Python involvement.
//!
//! Threading model: the `xla` wrapper types hold raw pointers and are
//! not `Send`, so an [`Engine`] is pinned to the OS thread that created
//! it (the coordinator gives each executor worker its own engine). The
//! shared [`crate::util::ThreadPool`] is therefore used only for
//! host-side tensor work around the engine, never for engine calls.
//! In offline builds `xla` resolves to the in-tree stub
//! (`rust/vendor/xla`): host-side literals work, `PjRtClient::cpu`
//! errors, and artifact-gated tests skip.

pub mod engine;
pub mod manifest;
pub mod value;

pub use engine::{Engine, EngineStats};
pub use manifest::{artifacts_available, Dtype, Entry, IoSpec, Manifest};
pub use value::Value;
