//! Typed host values crossing the runtime boundary, and the bridge to
//! XLA literals.

use anyhow::{anyhow, bail, Result};

use super::manifest::{Dtype, IoSpec};
use crate::Tensor;

/// A host-side value: what the coordinator and trainer traffic in.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32(Tensor::from_vec(&[], vec![x]))
    }

    pub fn i32_vec(data: Vec<i32>) -> Value {
        let shape = vec![data.len()];
        Value::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(_) => Dtype::F32,
            Value::I32 { .. } => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        let t = self.as_f32()?;
        if t.len() != 1 {
            bail!("expected a scalar, got shape {:?}", t.shape);
        }
        Ok(t.data[0])
    }

    /// Validate against a manifest spec.
    pub fn check(&self, spec: &IoSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "input '{}': shape {:?} does not match manifest {:?}",
                spec.name,
                self.shape(),
                spec.shape
            );
        }
        if self.dtype() != spec.dtype {
            bail!("input '{}': dtype mismatch", spec.name);
        }
        Ok(())
    }

    /// Convert into an XLA literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Value::F32(t) => {
                let bytes = t.to_le_bytes();
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &t.shape,
                    &bytes,
                )
                .map_err(|e| anyhow!("literal from tensor: {e:?}"))
            }
            Value::I32 { shape, data } => {
                let bytes: Vec<u8> =
                    data.iter().flat_map(|v| v.to_le_bytes()).collect();
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    shape,
                    &bytes,
                )
                .map_err(|e| anyhow!("literal from i32: {e:?}"))
            }
        }
    }

    /// Convert an XLA literal back into a host value.
    pub fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let arr = match shape {
            xla::Shape::Array(a) => a,
            other => bail!("expected array literal, got {other:?}"),
        };
        let dims: Vec<usize> = arr.dims().iter().map(|&d| d as usize).collect();
        match arr.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
                Ok(Value::F32(Tensor::from_vec(&dims, data)))
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?;
                Ok(Value::I32 { shape: dims, data })
            }
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let v = Value::F32(t.clone());
        let lit = v.to_literal().unwrap();
        let back = Value::from_literal(&lit).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let v = Value::i32_vec(vec![7, -3, 0, 42]);
        let lit = v.to_literal().unwrap();
        assert_eq!(Value::from_literal(&lit).unwrap(), v);
    }

    #[test]
    fn scalar_roundtrip() {
        let v = Value::scalar_f32(3.25);
        let lit = v.to_literal().unwrap();
        let back = Value::from_literal(&lit).unwrap();
        assert_eq!(back.scalar().unwrap(), 3.25);
    }

    #[test]
    fn check_against_spec() {
        let spec = IoSpec { name: "x".into(), shape: vec![2, 2], dtype: Dtype::F32 };
        assert!(Value::F32(Tensor::zeros(&[2, 2])).check(&spec).is_ok());
        assert!(Value::F32(Tensor::zeros(&[2, 3])).check(&spec).is_err());
        assert!(Value::i32_vec(vec![1, 2, 3, 4]).check(&spec).is_err());
    }
}
