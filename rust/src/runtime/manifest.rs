//! The artifact manifest: the machine-readable contract between the
//! Python AOT pipeline (`python/compile/aot.py`) and this runtime.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u32" => Dtype::U32,
            other => bail!("unknown dtype '{other}'"),
        })
    }

    pub fn bytes(&self) -> usize {
        4
    }
}

/// One input or output slot of an artifact.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<IoSpec> {
        let name = j.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string();
        let shape = j
            .get("shape")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("io spec missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            j.get("dtype").and_then(|v| v.as_str()).unwrap_or("f32"),
        )?;
        Ok(IoSpec { name, shape, dtype })
    }
}

/// One compiled entrypoint.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// The first `n_params` inputs are model parameters.
    pub n_params: usize,
    pub params_bin: Option<String>,
    pub meta: Json,
}

impl Entry {
    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str())
    }

    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize())
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<Entry>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let dir = PathBuf::from(dir);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let entries = j
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing entries"))?
            .iter()
            .map(|e| {
                let name = e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("entry missing name"))?
                    .to_string();
                let file = e
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("entry missing file"))?
                    .to_string();
                let inputs = e
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = e
                    .get("outputs")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let n_params = e.get("n_params").and_then(|v| v.as_usize()).unwrap_or(0);
                let params_bin = e
                    .get("params_bin")
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string());
                Ok(Entry {
                    name,
                    file,
                    inputs,
                    outputs,
                    n_params,
                    params_bin,
                    meta: e.get("meta").cloned().unwrap_or(Json::Null),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir, entries })
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("no artifact named '{name}'"))
    }

    pub fn hlo_path(&self, entry: &Entry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// All entries whose meta.kind matches.
    pub fn by_kind(&self, kind: &str) -> Vec<&Entry> {
        self.entries
            .iter()
            .filter(|e| e.meta_str("kind") == Some(kind))
            .collect()
    }

    /// Load the initial parameter tensors for an entry from its params.bin.
    pub fn load_params(&self, entry: &Entry) -> Result<Vec<crate::Tensor>> {
        let bin = entry
            .params_bin
            .as_ref()
            .ok_or_else(|| anyhow!("entry {} has no params_bin", entry.name))?;
        let bytes = std::fs::read(self.dir.join(bin))
            .with_context(|| format!("reading {bin}"))?;
        slice_params(&bytes, &entry.inputs[..entry.n_params])
    }
}

/// Slice a concatenated little-endian f32 blob into tensors per spec.
pub fn slice_params(bytes: &[u8], specs: &[IoSpec]) -> Result<Vec<crate::Tensor>> {
    let total: usize = specs.iter().map(|s| s.elems() * 4).sum();
    if bytes.len() != total {
        bail!("params.bin is {} bytes, manifest wants {total}", bytes.len());
    }
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0;
    for s in specs {
        let n = s.elems() * 4;
        out.push(crate::Tensor::from_le_bytes(&s.shape, &bytes[off..off + n]));
        off += n;
    }
    Ok(out)
}

/// Check whether `path` exists relative to the manifest dir.
pub fn artifacts_available(dir: &str) -> bool {
    Path::new(dir).join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "scan_a", "file": "scan_a.hlo.txt", "n_params": 0,
         "params_bin": null,
         "inputs": [{"name": "x", "shape": [1, 8, 64, 64], "dtype": "f32"},
                    {"name": "a", "shape": [1, 1, 3, 64, 64], "dtype": "f32"}],
         "outputs": [{"name": "o0", "shape": [1, 8, 64, 64], "dtype": "f32"}],
         "meta": {"kind": "scan", "n": 1}},
        {"name": "net_fwd", "file": "net.hlo.txt", "n_params": 2,
         "params_bin": "net.params.bin",
         "inputs": [{"name": "p0", "shape": [4], "dtype": "f32"},
                    {"name": "p1", "shape": [2, 2], "dtype": "f32"},
                    {"name": "y", "shape": [4], "dtype": "i32"}],
         "outputs": [{"name": "o0", "shape": [], "dtype": "f32"}],
         "meta": {"kind": "classifier"}}
      ]
    }"#;

    fn sample() -> Manifest {
        Manifest::parse(SAMPLE, PathBuf::from("/tmp/none")).unwrap()
    }

    #[test]
    fn parses_entries() {
        let m = sample();
        assert_eq!(m.entries.len(), 2);
        let e = m.get("scan_a").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![1, 8, 64, 64]);
        assert_eq!(e.inputs[1].elems(), 3 * 64 * 64);
        assert_eq!(e.meta_usize("n"), Some(1));
    }

    #[test]
    fn dtype_parsing() {
        let m = sample();
        let e = m.get("net_fwd").unwrap();
        assert_eq!(e.inputs[2].dtype, Dtype::I32);
        assert_eq!(e.outputs[0].shape, Vec::<usize>::new());
        assert!(Dtype::parse("f64").is_err());
    }

    #[test]
    fn missing_entry_errors() {
        assert!(sample().get("nope").is_err());
    }

    #[test]
    fn by_kind_filters() {
        let m = sample();
        assert_eq!(m.by_kind("scan").len(), 1);
        assert_eq!(m.by_kind("classifier").len(), 1);
        assert!(m.by_kind("other").is_empty());
    }

    #[test]
    fn slice_params_roundtrip() {
        let m = sample();
        let e = m.get("net_fwd").unwrap();
        let vals: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let ts = slice_params(&bytes, &e.inputs[..2]).unwrap();
        assert_eq!(ts[0].shape, vec![4]);
        assert_eq!(ts[1].shape, vec![2, 2]);
        assert_eq!(ts[1].data, vec![4.0, 5.0, 6.0, 7.0]);
        assert!(slice_params(&bytes[..4], &e.inputs[..2]).is_err());
    }
}
