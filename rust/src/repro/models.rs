//! Model-level reproductions: Table 2, Fig S1, Table S2 (classification),
//! Fig 5 and Table S1 (text-to-image), plus the small-scale accuracy
//! proxy that validates the "matches transformers on a global-context
//! task" claim with real training through the artifacts.

use super::table::{f1, f2, Table};
use crate::gpusim::{attention, Backend, DeviceSpec, DiffusionModel};
use crate::model::{self, GspnArch};
use crate::runtime::{artifacts_available, Engine};
use crate::train::train_classifier;

/// Table 2: params / MACs / accuracy across the three scales.
pub fn table2(dev: &DeviceSpec, out: &str) -> Table {
    let mut t = Table::new(
        "Table 2 — ImageNet-1K at 224^2 (GSPN rows computed, baselines quoted)",
        &["model", "type", "params (M)", "MACs (G)", "acc (%)", "source"],
    );
    let _ = dev;
    for group in [model::tiny_group(), model::small_group(), model::base_group()] {
        for r in group {
            t.row(vec![
                r.model.clone(),
                r.backbone.tag().into(),
                if r.params_m > 0.0 { f1(r.params_m) } else { "-".into() },
                if r.macs_g > 0.0 { f1(r.macs_g) } else { "-".into() },
                f1(r.acc),
                if r.computed { "computed" } else { "paper" }.into(),
            ]);
        }
    }
    for (name, p, m, acc) in model::paper_targets() {
        t.note(&format!("paper target for {name}: {p} M / {m} G / {acc}%"));
    }
    t.note("GSPN-2 accuracy columns are the paper's reported numbers; the param/MAC \
            columns are recomputed exactly from the architecture (see arch.rs)");
    t.emit(out, "table2_imagenet");
    t
}

/// The small-scale accuracy proxy behind Table 2's accuracy claim:
/// train the GSPN classifier and the attention baseline on the
/// directional-context task through the real artifacts.
pub fn table2_proxy(out: &str, steps: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table 2 proxy — directional-context accuracy (trained via PJRT artifacts)",
        &["model", "params", "steps", "final loss", "eval acc (%)"],
    );
    if !artifacts_available("artifacts") {
        t.note("SKIPPED: artifacts/ not built");
        t.emit(out, "table2_proxy");
        return Ok(t);
    }
    let engine = Engine::cpu("artifacts")?;
    for m in ["classifier", "attn_classifier"] {
        let rep = train_classifier(&engine, m, steps, (steps / 10).max(1), steps / 2, 42)?;
        let trainer = crate::train::Trainer::new(&engine, m)?;
        t.row(vec![
            if m == "classifier" { "GSPN-2 (tiny)" } else { "attention (tiny)" }.into(),
            trainer.param_count().to_string(),
            steps.to_string(),
            f2(rep.final_train_loss),
            f1(rep.final_eval_acc * 100.0),
        ]);
    }
    t.note("claim checked: GSPN matches the attention baseline on a task that \
            requires global spatial context (random-guess accuracy = 12.5%)");
    t.emit(out, "table2_proxy");
    Ok(t)
}

/// Fig S1: accuracy / throughput / params scatter (data table form).
pub fn figs1(dev: &DeviceSpec, out: &str) -> Table {
    let mut t = Table::new(
        "Fig S1 — accuracy vs throughput vs size (tiny group)",
        &["model", "params (M)", "acc (%)", "throughput (img/s)", "source"],
    );
    for r in model::tiny_group() {
        let thr = if r.computed {
            attention::classifier_throughput(dev, &model::gspn2_tiny(), 224, 64)
        } else {
            r.throughput
        };
        if thr > 0.0 {
            t.row(vec![
                r.model.clone(),
                f1(r.params_m),
                f1(r.acc),
                format!("{thr:.0}"),
                if r.computed { "computed" } else { "paper" }.into(),
            ]);
        }
    }
    t.note("paper reports 1544 img/s for GSPN-2-T");
    t.emit(out, "figs1_scatter");
    t
}

/// Table S2: the C_proxy ablation (throughput computed, accuracy quoted).
pub fn tables2(dev: &DeviceSpec, out: &str) -> Table {
    let paper: [(usize, f64, f64); 5] = [
        (2, 83.0, 1544.0),
        (4, 83.0, 1492.0),
        (8, 83.0, 1387.0),
        (16, 82.9, 1293.0),
        (32, 82.8, 1106.0),
    ];
    let mut t = Table::new(
        "Table S2 — proxy-dimension ablation (GSPN-2-T)",
        &["C_proxy", "acc paper (%)", "throughput sim", "throughput paper"],
    );
    for (p, acc, thr_paper) in paper {
        let arch = GspnArch { c_proxy: p, ..model::gspn2_tiny() };
        let thr = attention::classifier_throughput(dev, &arch, 224, 64);
        t.row(vec![
            p.to_string(),
            f1(acc),
            format!("{thr:.0} img/s"),
            format!("{thr_paper:.0} img/s"),
        ]);
    }
    t.note("trend check: throughput decreases monotonically with C_proxy; \
            accuracy is flat (paper: -0.2% over 16x compression)");
    t.emit(out, "tables2_proxy_ablation");
    t
}

/// Fig 5: text-to-image inference time vs resolution.
pub fn fig5(dev: &DeviceSpec, out: &str) -> Table {
    let m = DiffusionModel::sdxl_like();
    let mut t = Table::new(
        "Fig 5 — SDXL-like generation time vs resolution (30 denoise steps)",
        &["resolution", "SDXL dense", "SDXL flash", "GSPN-1", "GSPN-2", "speedup vs flash"],
    );
    for res in [1024usize, 2048, 4096, 8192, 16384] {
        let dense = m.generate_s(dev, res, Backend::SdxlDense);
        let flash = m.generate_s(dev, res, Backend::SdxlFlash);
        let g1 = m.generate_s(dev, res, Backend::Gspn1);
        let g2 = m.generate_s(dev, res, Backend::Gspn2);
        t.row(vec![
            format!("{res}x{res}"),
            format!("{dense:.1} s"),
            format!("{flash:.1} s"),
            format!("{g1:.1} s"),
            format!("{g2:.2} s"),
            format!("{:.0}x", flash / g2),
        ]);
    }
    t.note("paper: 32x at 4K, 93x at 16K vs SDXL. Our dense-attention baseline is \
            extrapolated beyond 4K (real SDXL cannot run dense attention at 16K), \
            so the 16K ratio overshoots the paper's measured pipeline — see \
            EXPERIMENTS.md for the discrepancy analysis.");
    t.emit(out, "fig5_diffusion");
    t
}

/// Table S1: COCO 1024^2 quality (quoted) + our denoising-proxy numbers.
pub fn tables1(out: &str, proxy_steps: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table S1 — COCO 1024^2 generation quality (paper) + denoising proxy (ours)",
        &["model", "FID (paper)", "CLIP-T (paper)", "proxy denoise loss (ours)"],
    );
    let paper_rows = [
        ("SD-v1.5 (baseline)", "32.71", "0.290"),
        ("Mamba (w/ norm)", "50.30", "0.263"),
        ("Mamba2 (w/ norm)", "37.02", "0.273"),
        ("Linfusion (w/ norm)", "36.33", "0.285"),
        ("GSPN-1", "30.86", "0.307"),
        ("GSPN-2 (ours)", "33.21", "0.286"),
    ];
    let mut proxy_loss = String::from("-");
    if artifacts_available("artifacts") && proxy_steps > 0 {
        let engine = Engine::cpu("artifacts")?;
        let rep = crate::train::train_denoiser(&engine, proxy_steps, proxy_steps.max(1), 7)?;
        proxy_loss = format!(
            "{:.4} -> {:.4}",
            rep.curve.first().map(|l| l.loss).unwrap_or(0.0),
            rep.final_train_loss
        );
    }
    for (i, (m, fid, clip)) in paper_rows.iter().enumerate() {
        let ours = if i == paper_rows.len() - 1 { proxy_loss.clone() } else { "-".into() };
        t.row(vec![m.to_string(), fid.to_string(), clip.to_string(), ours]);
    }
    t.note("COCO/FID/CLIP-T are not reproducible without the generation stack; the \
            proxy column shows our GSPN-2 denoiser learning on the structured-image \
            task (decreasing epsilon-prediction loss), per DESIGN.md §1 substitutions");
    t.emit(out, "tables1_quality");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> DeviceSpec {
        DeviceSpec::a100_sxm4_80gb()
    }

    #[test]
    fn table2_contains_all_gspn2_rows() {
        let t = table2(&dev(), "/tmp/gspn2_test_out");
        for name in ["GSPN-2-T (Ours)", "GSPN-2-S (Ours)", "GSPN-2-B (Ours)"] {
            assert!(t.rows.iter().any(|r| r[0] == name), "missing {name}");
        }
        assert!(t.rows.len() > 40);
    }

    #[test]
    fn tables2_throughput_monotone() {
        let t = tables2(&dev(), "/tmp/gspn2_test_out");
        let vals: Vec<f64> = t
            .rows
            .iter()
            .map(|r| r[2].trim_end_matches(" img/s").parse().unwrap())
            .collect();
        for w in vals.windows(2) {
            assert!(w[1] < w[0], "throughput not monotone: {vals:?}");
        }
    }

    #[test]
    fn fig5_speedup_grows() {
        let t = fig5(&dev(), "/tmp/gspn2_test_out");
        let s: Vec<f64> = t
            .rows
            .iter()
            .map(|r| r[5].trim_end_matches('x').parse().unwrap())
            .collect();
        assert!(s.last().unwrap() > s.first().unwrap());
    }

    #[test]
    fn figs1_has_ours_computed() {
        let t = figs1(&dev(), "/tmp/gspn2_test_out");
        assert!(t.rows.iter().any(|r| r[0].contains("Ours") && r[4] == "computed"));
    }
}
