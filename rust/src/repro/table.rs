//! Table formatting + persistence for the repro drivers: every paper
//! table/figure is regenerated as an aligned text table on stdout and a
//! CSV under `bench_out/`.

use std::fmt::Write as _;

#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: &str) {
        self.notes.push(s.to_string());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("| ");
            for i in 0..ncol {
                let _ = write!(s, "{:<w$} | ", cells[i], w = widths[i]);
            }
            let _ = writeln!(out, "{}", s.trim_end());
        };
        line(&mut out, &self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&mut out, &sep);
        for r in &self.rows {
            line(&mut out, r);
        }
        for n in &self.notes {
            let _ = writeln!(out, "  note: {n}");
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Print to stdout and persist under `<out_dir>/<slug>.{txt,csv}`.
    pub fn emit(&self, out_dir: &str, slug: &str) {
        print!("{}", self.render());
        println!();
        let _ = std::fs::create_dir_all(out_dir);
        let _ = std::fs::write(format!("{out_dir}/{slug}.txt"), self.render());
        let _ = std::fs::write(format!("{out_dir}/{slug}.csv"), self.to_csv());
    }
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn ms(x: f64) -> String {
    format!("{x:.2} ms")
}
pub fn speedup(x: f64) -> String {
    format!("{x:.1}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["short".into(), "1".into()]);
        t.row(vec!["much longer name".into(), "22.5".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        let lines: Vec<&str> = r.lines().filter(|l| l.starts_with('|')).collect();
        // All table lines share the same width.
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{r}");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["with,comma".into(), "with\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"with,comma\""));
        assert!(csv.contains("\"with\"\"quote\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }
}
