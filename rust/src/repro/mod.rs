//! Reproduction drivers: one entry per table and figure in the paper's
//! evaluation (the DESIGN.md §3 experiment index). Each regenerates its
//! artifact as an aligned table on stdout plus `.txt`/`.csv` files in
//! `bench_out/`.
//!
//! | id       | paper artifact                                   |
//! |----------|--------------------------------------------------|
//! | fig1     | headline kernel comparison vs attention variants |
//! | fig3     | step-by-step optimisation, main config           |
//! | fig4     | fwd/bwd runtime vs resolution and channels       |
//! | table1   | global memory throughput (8 configs)             |
//! | table2   | ImageNet params/MACs/accuracy zoo                |
//! | proxy2   | small-scale accuracy proxy (trains via PJRT)     |
//! | fig5     | SDXL-like generation time vs resolution          |
//! | figs1    | accuracy/throughput/size scatter                 |
//! | figs2    | runtime vs BSxC (concurrency saturation)         |
//! | figs3    | step-by-step, large-batch config                 |
//! | figs4    | step-by-step, large-channel config               |
//! | tables1  | COCO quality (quoted) + denoising proxy          |
//! | tables2  | C_proxy ablation                                 |
//! | knee     | §4.2 concurrency-knee validation                 |
//! | ablation | leave-one-out over the GSPN-2 optimisations      |
//! | adaptive | appendix-B adaptive config selection (extension) |
//! | devices  | cross-device sweep V100/A30/A100/H100 (extension)|

pub mod kernels;
pub mod models;
pub mod table;

pub use table::Table;

use crate::gpusim::DeviceSpec;

pub const ALL: [&str; 17] = [
    "fig1", "fig3", "fig4", "table1", "table2", "proxy2", "fig5", "figs1", "figs2",
    "figs3", "figs4", "tables1", "tables2", "knee", "ablation", "adaptive", "devices",
];

/// Run one reproduction by id. `proxy_steps` bounds the artifact-training
/// proxies (`proxy2`, `tables1`) so CI stays fast.
pub fn run(id: &str, dev: &DeviceSpec, out: &str, proxy_steps: usize) -> anyhow::Result<()> {
    match id {
        "fig1" => {
            kernels::fig1(dev, out);
        }
        "fig3" => {
            kernels::fig3(dev, out);
        }
        "fig4" => {
            kernels::fig4(dev, out);
        }
        "table1" => {
            kernels::table1(dev, out);
        }
        "table2" => {
            models::table2(dev, out);
        }
        "proxy2" => {
            models::table2_proxy(out, proxy_steps)?;
        }
        "fig5" => {
            models::fig5(dev, out);
        }
        "figs1" => {
            models::figs1(dev, out);
        }
        "figs2" => {
            kernels::figs2(dev, out);
        }
        "figs3" => {
            kernels::figs3(dev, out);
        }
        "figs4" => {
            kernels::figs4(dev, out);
        }
        "tables1" => {
            models::tables1(out, proxy_steps.min(30))?;
        }
        "tables2" => {
            models::tables2(dev, out);
        }
        "knee" => {
            kernels::knee(dev, out);
        }
        "ablation" => {
            kernels::ablation(dev, out);
        }
        "adaptive" => {
            kernels::adaptive(dev, out);
        }
        "devices" => {
            kernels::devices(out);
        }
        "all" => {
            for id in ALL {
                run(id, dev, out, proxy_steps)?;
            }
        }
        other => anyhow::bail!("unknown repro id '{other}' (try: {} or all)", ALL.join(", ")),
    }
    Ok(())
}
