//! Simulator-driven reproductions: the kernel-level tables and figures
//! (Fig 1, Fig 3, Fig 4, Table 1, Fig S2, Fig S3, Fig S4).

use super::table::{f1, ms, speedup, Table};
use crate::gpusim::{
    attention, simulate, DeviceSpec, KernelConfig, PaperPipeline, ScanWorkload, FIG3,
    FIG_S3, FIG_S4,
};

fn pipeline_table(dev: &DeviceSpec, p: &PaperPipeline, slug_note: &str) -> Table {
    let mut t = Table::new(
        &format!("{} — step-by-step kernel optimisation", p.label),
        &["stage", "sim time", "paper", "step gain", "cum speedup", "achieved %peak"],
    );
    let results = p.run(dev);
    for (r, paper) in results.iter().zip(p.paper_ms) {
        t.row(vec![
            r.name.to_string(),
            ms(r.time_ms),
            ms(paper),
            speedup(r.step_speedup),
            speedup(r.cum_speedup),
            format!("{:.1}%", r.sim.pct_peak),
        ]);
    }
    let total = results.last().unwrap().cum_speedup;
    let paper_total = p.paper_ms[0] / p.paper_ms[5];
    t.note(&format!(
        "cumulative speedup: simulated {total:.1}x vs paper {paper_total:.1}x {slug_note}"
    ));
    t
}

/// Fig 3: main config (1024^2, bs16, 8ch), 71.4 -> 1.8 ms (40x).
pub fn fig3(dev: &DeviceSpec, out: &str) -> Table {
    let t = pipeline_table(dev, &FIG3, "(paper conclusion claims 'up to 52x')");
    t.emit(out, "fig3_pipeline");
    t
}

/// Fig S3: large batch (1024^2, bs256, 1ch) — SRAM hurts here.
pub fn figs3(dev: &DeviceSpec, out: &str) -> Table {
    let t = pipeline_table(dev, &FIG_S3, "(SRAM stage is expected to be a ~0.9x slowdown)");
    t.emit(out, "figs3_pipeline");
    t
}

/// Fig S4: large channels (1024^2, bs1, 1152ch) — compressive dominates.
pub fn figs4(dev: &DeviceSpec, out: &str) -> Table {
    let t = pipeline_table(dev, &FIG_S4, "(compressive stage is the dominant gain)");
    t.emit(out, "figs4_pipeline");
    t
}

/// Table 1: global memory throughput, GSPN-1 vs GSPN-2, 8 configs.
pub fn table1(dev: &DeviceSpec, out: &str) -> Table {
    let rows: [(usize, usize, usize, f64, f64); 8] = [
        // (batch, channels, size, paper GSPN-1 GB/s, paper GSPN-2 GB/s)
        (32, 196, 32, 114.0, 1832.0),
        (1, 768, 64, 86.0, 1847.0),
        (1, 1152, 64, 35.0, 1837.0),
        (1, 32, 64, 125.0, 1830.0),
        (1, 32, 128, 98.0, 1865.0),
        (1, 64, 256, 76.0, 1842.0),
        (8, 64, 256, 94.0, 1858.0),
        (1, 128, 512, 64.0, 1840.0),
    ];
    let mut t = Table::new(
        "Table 1 — global memory throughput on A100",
        &["input", "batch", "ch", "GSPN-1 sim", "GSPN-1 paper", "GSPN-2 sim", "GSPN-2 paper"],
    );
    for (n, c, s, p1, p2) in rows {
        let wl = ScanWorkload::fwd(n, c, s, s);
        let g1 = simulate(dev, &wl, &KernelConfig::gspn1());
        let g2 = simulate(dev, &wl, &KernelConfig::gspn2());
        t.row(vec![
            format!("{s}x{s}"),
            n.to_string(),
            c.to_string(),
            format!("{:.0} GB/s ({:.1}%)", g1.achieved_gbs, g1.pct_peak),
            format!("{:.0} GB/s ({:.1}%)", p1, p1 / dev.peak_bw_gbs * 100.0),
            format!("{:.0} GB/s ({:.1}%)", g2.achieved_gbs, g2.pct_peak),
            format!("{:.0} GB/s ({:.1}%)", p2, p2 / dev.peak_bw_gbs * 100.0),
        ]);
    }
    t.note("band check: GSPN-1 in the paper's 2-8% regime, GSPN-2 in the 90%+ regime");
    t.emit(out, "table1_throughput");
    t
}

/// Fig 4: forward/backward runtime vs resolution and vs channel count.
pub fn fig4(dev: &DeviceSpec, out: &str) -> Table {
    let mut t = Table::new(
        "Fig 4 — runtime vs resolution / channels (GSPN-1 vs GSPN-2)",
        &["config", "pass", "GSPN-1", "GSPN-2", "speedup"],
    );
    for res in [128usize, 256, 512, 1024, 2048] {
        for bwd in [false, true] {
            let wl = if bwd {
                ScanWorkload::bwd(4, 8, res, res)
            } else {
                ScanWorkload::fwd(4, 8, res, res)
            };
            let g1 = simulate(dev, &wl, &KernelConfig::gspn1()).time_ms;
            let g2 = simulate(dev, &wl, &KernelConfig::gspn2()).time_ms;
            t.row(vec![
                format!("{res}x{res} b4 c8"),
                if bwd { "bwd" } else { "fwd" }.into(),
                ms(g1),
                ms(g2),
                speedup(g1 / g2),
            ]);
        }
    }
    for c in [8usize, 32, 64, 128, 256, 512, 1024] {
        for bwd in [false, true] {
            let wl = if bwd {
                ScanWorkload::bwd(4, c, 512, 512)
            } else {
                ScanWorkload::fwd(4, c, 512, 512)
            };
            let g1 = simulate(dev, &wl, &KernelConfig::gspn1()).time_ms;
            let g2 = simulate(dev, &wl, &KernelConfig::with_proxy(8)).time_ms;
            t.row(vec![
                format!("512x512 b4 c{c}"),
                if bwd { "bwd" } else { "fwd" }.into(),
                ms(g1),
                ms(g2),
                speedup(g1 / g2),
            ]);
        }
    }
    t.note("paper: up to 36.8x fwd / 25.3x bwd at 1024^2; 27.4x fwd / 48.6x bwd at 256 ch");
    t.emit(out, "fig4_runtime");
    t
}

/// Fig S2: runtime vs BS x C product — the concurrency saturation story.
pub fn figs2(dev: &DeviceSpec, out: &str) -> Table {
    let mut t = Table::new(
        "Fig S2 — forward runtime vs BSxC (64^2 latents)",
        &["BSxC", "blocks (G1 step)", "GSPN-1", "GSPN-2", "speedup"],
    );
    for bsc in [32usize, 128, 512, 1024, 2048, 3456, 4096, 8192, 16384] {
        let n = bsc.min(256);
        let c = bsc.div_ceil(n);
        let wl = ScanWorkload::fwd(n, c, 64, 64);
        let g1 = simulate(dev, &wl, &KernelConfig::gspn1());
        let g2 = simulate(dev, &wl, &KernelConfig::gspn2());
        t.row(vec![
            bsc.to_string(),
            g1.blocks.to_string(),
            ms(g1.time_ms),
            ms(g2.time_ms),
            speedup(g1.time_ms / g2.time_ms),
        ]);
    }
    let cap = dev.concurrency_capacity(512, 0);
    t.note(&format!(
        "GSPN-1 per-step grids saturate the device at ~{cap} concurrent blocks (paper: 3-4K)"
    ));
    t.emit(out, "figs2_bsc");
    t
}

/// Fig 1: headline comparison across attention variants.
pub fn fig1(dev: &DeviceSpec, out: &str) -> Table {
    let mut t = Table::new(
        "Fig 1 — GSPN-2 vs GSPN-1 and efficient-attention variants",
        &["tokens (side^2)", "softmax", "flash", "linear", "mamba", "GSPN-1", "GSPN-2", "G1/G2"],
    );
    for side in [64usize, 128, 256, 512] {
        let tokens = side * side;
        let c = 64;
        let soft = attention::attention_time_ms(dev, tokens, c, false);
        let flash = attention::attention_time_ms(dev, tokens, c, true);
        let lin = attention::linear_attention_time_ms(dev, tokens, c);
        let mamba = attention::mamba_scan_time_ms(dev, tokens, c, 16);
        let g1 = attention::gspn_module_time_ms(dev, 1, c, side, side, &KernelConfig::gspn1());
        let g2 = attention::gspn_module_time_ms(dev, 1, c, side, side, &KernelConfig::with_proxy(8));
        t.row(vec![
            format!("{side}^2"),
            ms(soft),
            ms(flash),
            ms(lin),
            ms(mamba),
            ms(g1),
            ms(g2),
            speedup(g1 / g2),
        ]);
    }
    t.note("paper: GSPN-2 runs 30-50x faster than GSPN-1 across configurations");
    t.emit(out, "fig1_headline");
    t
}

/// The concurrency-knee validation of §4.2 (supports Fig S2's narrative):
/// a latency-bound kernel shows near-constant runtime until the device
/// block capacity, then linear growth.
pub fn knee(dev: &DeviceSpec, out: &str) -> Table {
    let mut t = Table::new(
        "Concurrency knee — waves vs active blocks (latency-bound kernel)",
        &["blocks", "capacity", "waves", "relative runtime"],
    );
    // 64-thread blocks reach the cc-8.0 residency limit of 32 blocks/SM:
    // 108 x 32 = 3,456 — the paper's "roughly 3,500 blocks" ceiling.
    let cap = dev.concurrency_capacity(64, 0);
    for blocks in [cap / 4, cap / 2, cap, cap + 1, cap * 2, cap * 4] {
        let waves = blocks.div_ceil(cap);
        t.row(vec![
            blocks.to_string(),
            cap.to_string(),
            waves.to_string(),
            f1(waves as f64),
        ]);
    }
    t.note(&format!(
        "capacity = {} SMs x {} resident blocks (cc 8.0) = {cap} (paper: ~3,500)",
        dev.sms,
        cap / dev.sms
    ));
    t.emit(out, "knee_concurrency");
    t
}

/// Ablation: every single-optimisation toggle removed from full GSPN-2
/// (how much each mechanism contributes at the Fig-3 config).
pub fn ablation(dev: &DeviceSpec, out: &str) -> Table {
    let wl = FIG3.workload();
    let full = simulate(dev, &wl, &KernelConfig::gspn2()).time_ms;
    let mut t = Table::new(
        "Ablation — remove one optimisation from full GSPN-2 (Fig 3 config)",
        &["variant", "time", "slowdown vs full"],
    );
    t.row(vec!["full GSPN-2".into(), ms(full), speedup(1.0)]);
    let variants: Vec<(&str, KernelConfig)> = vec![
        ("- coalescing", KernelConfig { coalesced: false, ..KernelConfig::gspn2() }),
        ("- SRAM staging", KernelConfig { sram: false, ..KernelConfig::gspn2() }),
        ("- 2D blocks", KernelConfig { blocks2d: false, c_slice: 1, ..KernelConfig::gspn2() }),
        ("- shared taps", KernelConfig { shared_taps: false, ..KernelConfig::gspn2() }),
        ("- fusion (per-step)", KernelConfig { fused: false, ..KernelConfig::gspn2() }),
    ];
    for (name, cfg) in variants {
        let tms = simulate(dev, &wl, &cfg).time_ms;
        t.row(vec![name.into(), ms(tms), speedup(tms / full)]);
    }
    t.emit(out, "ablation_stages");
    t
}

/// Extension (appendix B): adaptive GSPN-1/GSPN-2 configuration
/// selection by input shape, vs the fixed full-GSPN-2 config.
pub fn adaptive(dev: &DeviceSpec, out: &str) -> Table {
    use crate::gpusim::adaptive::compare;
    let mut t = Table::new(
        "Adaptive kernel policy — fixed GSPN-2 vs shape-adaptive config",
        &["config", "fixed", "adaptive", "gain", "rules fired"],
    );
    let sweep: [(usize, usize, usize); 8] = [
        (1, 1, 2048),
        (1, 4, 1024),
        (1, 8, 512),
        (16, 8, 1024),
        (256, 1, 1024),
        (1, 1152, 1024),
        (64, 256, 256),
        (8, 64, 256),
    ];
    for (n, c, r) in sweep {
        let wl = ScanWorkload::fwd(n, c, r, r);
        let (fixed, ad, choice) = compare(dev, &wl);
        let rules = if choice.rationale.is_empty() {
            "(fixed optimal)".to_string()
        } else {
            choice
                .rationale
                .iter()
                .map(|r| r.split(':').next().unwrap_or(r))
                .collect::<Vec<_>>()
                .join(", ")
        };
        t.row(vec![
            format!("{r}x{r} b{n} c{c}"),
            ms(fixed),
            ms(ad),
            speedup(fixed / ad),
            rules,
        ]);
    }
    t.note(
        "appendix-B extension: shape-adaptive selection (sram/2d gating, model-searched \
         proxy + segment-split) never loses to the fixed config and wins up to several-fold \
         in the low-occupancy regime",
    );
    t.emit(out, "adaptive_policy");
    t
}

/// Extension: cross-device sweep (V100 / A30 / A100 / H100) of the Fig-3
/// headline config — the concurrency knee and speedup move with SM count
/// and bandwidth, showing the model is not A100-specific.
pub fn devices(out: &str) -> Table {
    let mut t = Table::new(
        "Cross-device sweep — Fig-3 config (1024^2, bs16, 8ch) per device",
        &["device", "SMs", "peak GB/s", "GSPN-1", "GSPN-2", "speedup", "knee (blocks)"],
    );
    for dev in DeviceSpec::all() {
        let wl = ScanWorkload::fwd(16, 8, 1024, 1024);
        let g1 = simulate(&dev, &wl, &KernelConfig::gspn1());
        let g2 = simulate(&dev, &wl, &KernelConfig::gspn2());
        t.row(vec![
            dev.name.clone(),
            dev.sms.to_string(),
            format!("{:.0}", dev.peak_bw_gbs),
            ms(g1.time_ms),
            ms(g2.time_ms),
            speedup(g1.time_ms / g2.time_ms),
            dev.concurrency_capacity(64, 0).to_string(),
        ]);
    }
    t.note("knee = max resident 64-thread blocks (SMs x 32); paper cites ~3.5K on A100");
    t.emit(out, "devices_sweep");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> DeviceSpec {
        DeviceSpec::a100_sxm4_80gb()
    }

    #[test]
    fn fig3_table_has_six_stages() {
        let t = pipeline_table(&dev(), &FIG3, "");
        assert_eq!(t.rows.len(), 6);
        assert!(t.rows[0][0].contains("GSPN-1"));
    }

    #[test]
    fn table1_has_eight_rows() {
        let t = table1(&dev(), "/tmp/gspn2_test_out");
        assert_eq!(t.rows.len(), 8);
    }

    #[test]
    fn fig4_covers_fwd_and_bwd() {
        let t = fig4(&dev(), "/tmp/gspn2_test_out");
        let fwd = t.rows.iter().filter(|r| r[1] == "fwd").count();
        let bwd = t.rows.iter().filter(|r| r[1] == "bwd").count();
        assert_eq!(fwd, bwd);
        assert!(fwd >= 10);
    }

    #[test]
    fn ablation_every_removal_slows_down() {
        let t = ablation(&dev(), "/tmp/gspn2_test_out");
        for row in &t.rows[1..] {
            let s: f64 = row[2].trim_end_matches('x').parse().unwrap();
            assert!(s >= 0.99, "{} sped things up: {s}", row[0]);
        }
    }

    #[test]
    fn fig1_gspn2_fastest_at_scale() {
        let t = fig1(&dev(), "/tmp/gspn2_test_out");
        let last = t.rows.last().unwrap();
        let parse = |s: &str| -> f64 { s.trim_end_matches(" ms").parse().unwrap() };
        let g2 = parse(&last[6]);
        for col in [1, 2, 5] {
            assert!(parse(&last[col]) > g2, "col {col} not slower than GSPN-2");
        }
    }
}
