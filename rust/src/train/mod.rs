//! Training driver: synthetic data generation + the SGD loop over the
//! AOT-compiled train-step artifacts (the end-to-end deliverable).

pub mod data;
pub mod driver;

pub use data::{denoising_batch, DirectionalContext, Sample, VoronoiSeg, NUM_CLASSES};
pub use driver::{train_classifier, train_denoiser, train_segmenter, StepLog, TrainReport, Trainer};
