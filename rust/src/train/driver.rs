//! Training driver: runs the AOT-compiled `*_train_*` artifacts in a
//! loop with Rust-generated data. Python never runs here — the full
//! optimiser step (forward, backward through the fused scan kernels,
//! SGD-momentum update) is one HLO module per step.

use anyhow::{bail, Result};

use super::data::{DirectionalContext, NUM_CLASSES};
use crate::runtime::{Engine, Value};
use crate::util::logging;
use crate::util::Rng;
use crate::Tensor;

#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: usize,
    pub loss: f64,
    pub ms: f64,
}

#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub curve: Vec<StepLog>,
    pub evals: Vec<(usize, f64, f64)>, // (step, loss, accuracy)
    pub final_train_loss: f64,
    pub final_eval_acc: f64,
    pub wall_s: f64,
    pub step_overhead_frac: f64,
}

impl TrainReport {
    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss,ms\n");
        for l in &self.curve {
            s.push_str(&format!("{},{:.6},{:.3}\n", l.step, l.loss, l.ms));
        }
        s
    }
}

/// Classifier trainer over the `{model}_train_b{N}` artifact family.
pub struct Trainer<'e> {
    engine: &'e Engine,
    pub model: String,
    train_entry: String,
    eval_entry: String,
    batch: usize,
    img: usize,
    k: usize,
    params: Vec<Value>,
    vel: Vec<Value>,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, model: &str) -> Result<Trainer<'e>> {
        // Discover the train-step entry for this model family.
        let entry = engine
            .manifest()
            .entries
            .iter()
            .find(|e| {
                e.meta_str("kind") == Some("train_step")
                    && e.meta_str("model") == Some(model)
            })
            .cloned();
        let Some(entry) = entry else {
            bail!("no train_step artifact for model '{model}'");
        };
        let batch = entry.meta_usize("batch").unwrap_or(8);
        let img = entry.meta_usize("img").unwrap_or(32);
        let k = entry.n_params;
        let params = engine.initial_params(&entry.name)?;
        let vel: Vec<Value> = params
            .iter()
            .map(|p| Value::F32(Tensor::zeros(p.shape())))
            .collect();
        let eval_entry = entry.name.replace("_train_", "_eval_");
        Ok(Trainer {
            engine,
            model: model.to_string(),
            train_entry: entry.name,
            eval_entry,
            batch,
            img,
            k,
            params,
            vel,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn image_size(&self) -> usize {
        self.img
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.shape().iter().product::<usize>()).sum()
    }

    /// One optimiser step; returns the loss.
    pub fn step(&mut self, x: Tensor, y: Vec<i32>) -> Result<f64> {
        assert_eq!(y.len(), self.batch);
        let mut inputs = Vec::with_capacity(2 * self.k + 2);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.vel.iter().cloned());
        inputs.push(Value::F32(x));
        inputs.push(Value::i32_vec(y));
        let mut out = self.engine.run(&self.train_entry, &inputs)?;
        let loss = out.pop().expect("loss output").scalar()? as f64;
        let vel: Vec<Value> = out.drain(self.k..).collect();
        let params: Vec<Value> = out;
        self.params = params;
        self.vel = vel;
        Ok(loss)
    }

    /// Evaluate on one batch; returns (loss, n_correct).
    pub fn eval(&self, x: Tensor, y: Vec<i32>) -> Result<(f64, usize)> {
        let mut inputs = Vec::with_capacity(self.k + 2);
        inputs.extend(self.params.iter().cloned());
        inputs.push(Value::F32(x));
        inputs.push(Value::i32_vec(y));
        let out = self.engine.run(&self.eval_entry, &inputs)?;
        let loss = out[0].scalar()? as f64;
        let correct = match &out[1] {
            Value::I32 { data, .. } => data[0] as usize,
            Value::F32(t) => t.data[0] as usize,
        };
        Ok((loss, correct))
    }
}

/// The end-to-end training loop (the E2E deliverable's engine room).
pub fn train_classifier(
    engine: &Engine,
    model: &str,
    steps: usize,
    log_every: usize,
    eval_every: usize,
    seed: u64,
) -> Result<TrainReport> {
    let t_start = std::time::Instant::now();
    let mut trainer = Trainer::new(engine, model)?;
    let b = trainer.batch_size();
    let img = trainer.image_size();
    logging::info(
        "train",
        &format!(
            "model={model} params={} batch={b} img={img} classes<= {NUM_CLASSES}",
            trainer.param_count()
        ),
    );
    let mut ds = DirectionalContext::new(img, seed);
    let mut eval_ds = DirectionalContext::new(img, seed ^ 0xe7a1);
    let mut report = TrainReport::default();
    let mut exec_ms_total = 0.0;

    for step in 0..steps {
        let (x, y) = ds.batch(b);
        let t0 = std::time::Instant::now();
        let loss = trainer.step(x, y)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        exec_ms_total += ms;
        if step % log_every == 0 || step + 1 == steps {
            logging::info("train", &format!("step {step:>5}  loss {loss:.4}  ({ms:.0} ms)"));
            report.curve.push(StepLog { step, loss, ms });
        }
        report.final_train_loss = loss;

        if eval_every > 0 && (step + 1) % eval_every == 0 {
            let (ex, ey) = eval_ds.batch(b);
            let mut correct = 0;
            let mut eloss = 0.0;
            let evals = 4;
            let (l, c) = trainer.eval(ex, ey)?;
            eloss += l;
            correct += c;
            let mut total = b;
            for _ in 1..evals {
                let (ex, ey) = eval_ds.batch(b);
                let (l, c) = trainer.eval(ex, ey)?;
                eloss += l;
                correct += c;
                total += b;
            }
            let acc = correct as f64 / total as f64;
            logging::info(
                "train",
                &format!("  eval @ {step}: loss {:.4} acc {:.1}%", eloss / evals as f64, acc * 100.0),
            );
            report.evals.push((step, eloss / evals as f64, acc));
            report.final_eval_acc = acc;
        }
    }
    report.wall_s = t_start.elapsed().as_secs_f64();
    let wall_ms = report.wall_s * 1e3;
    report.step_overhead_frac = ((wall_ms - exec_ms_total) / wall_ms).max(0.0);
    Ok(report)
}

/// Denoiser training loop (DDPM epsilon objective) over the
/// `denoiser_train_*` artifact.
pub fn train_denoiser(
    engine: &Engine,
    steps: usize,
    log_every: usize,
    seed: u64,
) -> Result<TrainReport> {
    let entry = engine
        .manifest()
        .by_kind("denoise_train_step")
        .first()
        .cloned()
        .cloned();
    let Some(entry) = entry else {
        bail!("no denoiser train artifact");
    };
    let batch = entry.meta_usize("batch").unwrap_or(4);
    let res = entry.meta_usize("res").unwrap_or(16);
    let k = entry.n_params;
    let mut params = engine.initial_params(&entry.name)?;
    let mut rng = Rng::new(seed ^ 0xdd);
    let mut report = TrainReport::default();
    let t_start = std::time::Instant::now();

    for step in 0..steps {
        let x0 = super::data::denoising_batch(&mut rng, batch, res);
        let noise = Tensor::randn(&[batch, 3, res, res], &mut rng, 1.0);
        let t: Vec<i32> = (0..batch).map(|_| rng.below(100) as i32).collect();
        let mut inputs = Vec::with_capacity(k + 3);
        inputs.extend(params.iter().cloned());
        inputs.push(Value::F32(x0));
        inputs.push(Value::F32(noise));
        inputs.push(Value::i32_vec(t));
        let t0 = std::time::Instant::now();
        let mut out = engine.run(&entry.name, &inputs)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let loss = out.pop().expect("loss").scalar()? as f64;
        params = out;
        if step % log_every == 0 || step + 1 == steps {
            logging::info("train", &format!("denoise step {step:>5} loss {loss:.4} ({ms:.0} ms)"));
            report.curve.push(StepLog { step, loss, ms });
        }
        report.final_train_loss = loss;
    }
    report.wall_s = t_start.elapsed().as_secs_f64();
    Ok(report)
}

/// Dense-prediction training loop (§6 extension) over the
/// `segmenter_train_*` artifact: per-pixel cross-entropy on the synthetic
/// Voronoi task. Returns the usual report; `final_eval_acc` is *pixel*
/// accuracy.
pub fn train_segmenter(
    engine: &Engine,
    steps: usize,
    log_every: usize,
    eval_every: usize,
    seed: u64,
) -> Result<TrainReport> {
    let entry = engine
        .manifest()
        .by_kind("seg_train_step")
        .first()
        .cloned()
        .cloned();
    let Some(entry) = entry else {
        bail!("no segmenter train artifact (rebuild artifacts)");
    };
    let batch = entry.meta_usize("batch").unwrap_or(4);
    let img = entry.meta_usize("img").unwrap_or(32);
    let k = entry.n_params;
    let mut params = engine.initial_params(&entry.name)?;
    let mut vel: Vec<Value> =
        params.iter().map(|p| Value::F32(Tensor::zeros(p.shape()))).collect();
    let eval_entry = entry.name.replace("_train_", "_eval_");
    let mut ds = super::data::VoronoiSeg::new(img, seed);
    let mut eval_ds = super::data::VoronoiSeg::new(img, seed ^ 0x5e61);
    let mut report = TrainReport::default();
    let t_start = std::time::Instant::now();
    let mut exec_ms_total = 0.0;
    logging::info(
        "seg-train",
        &format!(
            "segmenter params={} batch={batch} img={img}",
            params.iter().map(|p| p.shape().iter().product::<usize>()).sum::<usize>()
        ),
    );

    for step in 0..steps {
        let (x, y) = ds.batch(batch);
        let mut inputs = Vec::with_capacity(2 * k + 2);
        inputs.extend(params.iter().cloned());
        inputs.extend(vel.iter().cloned());
        inputs.push(Value::F32(x));
        inputs.push(Value::I32 { shape: vec![batch, img, img], data: y });
        let t0 = std::time::Instant::now();
        let mut out = engine.run(&entry.name, &inputs)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        exec_ms_total += ms;
        let loss = out.pop().expect("loss").scalar()? as f64;
        let new_vel: Vec<Value> = out.drain(k..).collect();
        params = out;
        vel = new_vel;
        if step % log_every == 0 || step + 1 == steps {
            logging::info("seg-train", &format!("step {step:>5}  loss {loss:.4}  ({ms:.0} ms)"));
            report.curve.push(StepLog { step, loss, ms });
        }
        report.final_train_loss = loss;

        if eval_every > 0 && (step + 1) % eval_every == 0 {
            let (ex, ey) = eval_ds.batch(batch);
            let total_px = batch * img * img;
            let mut inputs = Vec::with_capacity(k + 2);
            inputs.extend(params.iter().cloned());
            inputs.push(Value::F32(ex));
            inputs.push(Value::I32 { shape: vec![batch, img, img], data: ey });
            let out = engine.run(&eval_entry, &inputs)?;
            let eloss = out[0].scalar()? as f64;
            let correct = match &out[1] {
                Value::I32 { data, .. } => data[0] as usize,
                Value::F32(t) => t.data[0] as usize,
            };
            let acc = correct as f64 / total_px as f64;
            logging::info(
                "seg-train",
                &format!("  eval @ {step}: loss {eloss:.4} pixel-acc {:.1}%", acc * 100.0),
            );
            report.evals.push((step, eloss, acc));
            report.final_eval_acc = acc;
        }
    }
    report.wall_s = t_start.elapsed().as_secs_f64();
    let wall_ms = report.wall_s * 1e3;
    report.step_overhead_frac = ((wall_ms - exec_ms_total) / wall_ms).max(0.0);
    Ok(report)
}
