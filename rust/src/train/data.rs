//! Synthetic datasets.
//!
//! * `DirectionalContext` — the classification proxy for Table 2: each
//!   image contains two Gaussian blobs; the label is the octant of the
//!   displacement from blob A (bright) to blob B (dark). Solving it
//!   *requires* relating distant pixels — exactly the global spatial
//!   context GSPN's four-directional propagation provides — while being
//!   learnable by a ~50k-parameter model in a few hundred steps.
//! * `denoising_batch` — tiny structured images (random gradients +
//!   stripes) for the DDPM-style denoiser (the Fig 5 / Table S1 proxy).

use crate::util::Rng;
use crate::Tensor;

pub const NUM_CLASSES: usize = 8;

#[derive(Clone, Debug)]
pub struct Sample {
    pub image: Tensor, // (3, S, S)
    pub label: usize,  // octant of B relative to A
}

pub struct DirectionalContext {
    pub size: usize,
    rng: Rng,
}

impl DirectionalContext {
    pub fn new(size: usize, seed: u64) -> Self {
        Self { size, rng: Rng::new(seed ^ 0xda7a) }
    }

    fn blob(img: &mut Tensor, ch: usize, cy: f32, cx: f32, sigma: f32, amp: f32) {
        let s = img.shape[1];
        for y in 0..s {
            for x in 0..s {
                let d2 = (y as f32 - cy).powi(2) + (x as f32 - cx).powi(2);
                *img.at_mut(&[ch, y, x]) += amp * (-d2 / (2.0 * sigma * sigma)).exp();
            }
        }
    }

    pub fn sample(&mut self) -> Sample {
        let s = self.size as f32;
        // Keep the blobs apart so the octant is unambiguous.
        let (ay, ax, by, bx) = loop {
            let ay = self.rng.uniform_in(0.2 * s, 0.8 * s);
            let ax = self.rng.uniform_in(0.2 * s, 0.8 * s);
            let by = self.rng.uniform_in(0.1 * s, 0.9 * s);
            let bx = self.rng.uniform_in(0.1 * s, 0.9 * s);
            let d2 = (ay - by).powi(2) + (ax - bx).powi(2);
            if d2 > (0.25 * s).powi(2) {
                break (ay, ax, by, bx);
            }
        };
        let mut img = Tensor::zeros(&[3, self.size, self.size]);
        // Blob A bright in channel 0, blob B in channel 1; channel 2 noise.
        Self::blob(&mut img, 0, ay, ax, 0.10 * s, 1.5);
        Self::blob(&mut img, 1, by, bx, 0.10 * s, 1.5);
        for v in img.data.iter_mut() {
            *v += self.rng.normal_f32() * 0.05;
        }
        // Octant label from the displacement angle A -> B.
        let angle = (by - ay).atan2(bx - ax); // [-pi, pi]
        let oct = (((angle + std::f32::consts::PI) / (2.0 * std::f32::consts::PI)
            * NUM_CLASSES as f32)
            .floor() as usize)
            .min(NUM_CLASSES - 1);
        Sample { image: img, label: oct }
    }

    /// A batch as the (N,3,S,S) tensor + i32 labels the artifacts expect.
    pub fn batch(&mut self, n: usize) -> (Tensor, Vec<i32>) {
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let smp = self.sample();
            xs.push(smp.image);
            ys.push(smp.label as i32);
        }
        let refs: Vec<&Tensor> = xs.iter().collect();
        // concat of n (3,S,S) tensors is (3n,S,S) in sample-major order;
        // reinterpret as (n,3,S,S).
        let cat = crate::tensor::concat_axis0(&refs);
        let batch = Tensor::from_vec(&[n, 3, self.size, self.size], cat.data);
        (batch, ys)
    }
}

/// Structured tiny images for the denoiser: per-sample random linear
/// gradient plus sinusoidal stripes (so there is real signal to learn).
pub fn denoising_batch(rng: &mut Rng, n: usize, size: usize) -> Tensor {
    let mut out = Tensor::zeros(&[n, 3, size, size]);
    for i in 0..n {
        let gx = rng.uniform_in(-1.0, 1.0);
        let gy = rng.uniform_in(-1.0, 1.0);
        let freq = rng.uniform_in(0.5, 3.0);
        let phase = rng.uniform_in(0.0, std::f32::consts::TAU);
        for c in 0..3 {
            let cshift = c as f32 * 0.7;
            for y in 0..size {
                for x in 0..size {
                    let u = x as f32 / size as f32;
                    let v = y as f32 / size as f32;
                    let val = gx * u + gy * v
                        + 0.5 * (freq * std::f32::consts::TAU * (u + v) + phase + cshift).sin();
                    *out.at_mut(&[i, c, y, x]) = val;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_cover_octants() {
        let mut ds = DirectionalContext::new(32, 0);
        let mut seen = [false; NUM_CLASSES];
        for _ in 0..400 {
            let s = ds.sample();
            assert!(s.label < NUM_CLASSES);
            seen[s.label] = true;
        }
        assert!(seen.iter().all(|&b| b), "not all octants sampled: {seen:?}");
    }

    #[test]
    fn label_matches_geometry() {
        // Construct by hand: B strictly to the right of A -> angle 0 ->
        // octant (pi / 2pi * 8) = 4.
        let ay = 16.0f32;
        let ax = 8.0f32;
        let by = 16.0f32;
        let bx = 24.0f32;
        let angle = (by - ay).atan2(bx - ax);
        let oct = (((angle + std::f32::consts::PI) / (2.0 * std::f32::consts::PI) * 8.0)
            .floor() as usize)
            .min(7);
        assert_eq!(oct, 4);
    }

    #[test]
    fn batch_shapes() {
        let mut ds = DirectionalContext::new(32, 1);
        let (x, y) = ds.batch(8);
        assert_eq!(x.shape, vec![8, 3, 32, 32]);
        assert_eq!(y.len(), 8);
        assert!(x.abs_max() > 0.5, "images look empty");
    }

    #[test]
    fn batch_deterministic_per_seed() {
        let (a, la) = DirectionalContext::new(32, 7).batch(4);
        let (b, lb) = DirectionalContext::new(32, 7).batch(4);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn denoising_images_structured() {
        let mut rng = Rng::new(3);
        let x = denoising_batch(&mut rng, 2, 16);
        assert_eq!(x.shape, vec![2, 3, 16, 16]);
        // Not constant, bounded.
        assert!(x.abs_max() > 0.3 && x.abs_max() < 3.0);
        let mean = x.mean().abs();
        assert!(mean < 1.0);
    }
}

/// Dense-prediction task for the segmenter extension (§6): two marker
/// blobs; every pixel's label is the *nearer marker* (a 2-cell Voronoi
/// partition). The markers are sparse, so correct labels far from both
/// markers require global propagation of the marker positions — a local
/// model cannot place the bisector.
pub struct VoronoiSeg {
    pub size: usize,
    rng: Rng,
}

impl VoronoiSeg {
    pub fn new(size: usize, seed: u64) -> Self {
        Self { size, rng: Rng::new(seed ^ 0x5e6) }
    }

    /// One sample: image (3, S, S) and per-pixel labels (S*S,) in {0, 1}.
    pub fn sample(&mut self) -> (Tensor, Vec<i32>) {
        let s = self.size as f32;
        let (ay, ax, by, bx) = loop {
            let ay = self.rng.uniform_in(0.15 * s, 0.85 * s);
            let ax = self.rng.uniform_in(0.15 * s, 0.85 * s);
            let by = self.rng.uniform_in(0.15 * s, 0.85 * s);
            let bx = self.rng.uniform_in(0.15 * s, 0.85 * s);
            if (ay - by).powi(2) + (ax - bx).powi(2) > (0.3 * s).powi(2) {
                break (ay, ax, by, bx);
            }
        };
        let mut img = Tensor::zeros(&[3, self.size, self.size]);
        DirectionalContext::blob(&mut img, 0, ay, ax, 0.08 * s, 2.0);
        DirectionalContext::blob(&mut img, 1, by, bx, 0.08 * s, 2.0);
        for v in img.data.iter_mut() {
            *v += self.rng.normal_f32() * 0.05;
        }
        let mut labels = Vec::with_capacity(self.size * self.size);
        for y in 0..self.size {
            for x in 0..self.size {
                let da = (y as f32 - ay).powi(2) + (x as f32 - ax).powi(2);
                let db = (y as f32 - by).powi(2) + (x as f32 - bx).powi(2);
                labels.push(if da <= db { 0 } else { 1 });
            }
        }
        (img, labels)
    }

    /// A batch: (N,3,S,S) images + (N,S,S) labels flattened row-major.
    pub fn batch(&mut self, n: usize) -> (Tensor, Vec<i32>) {
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n * self.size * self.size);
        for _ in 0..n {
            let (img, lbl) = self.sample();
            xs.push(img);
            ys.extend(lbl);
        }
        let refs: Vec<&Tensor> = xs.iter().collect();
        let cat = crate::tensor::concat_axis0(&refs);
        let batch = Tensor::from_vec(&[n, 3, self.size, self.size], cat.data);
        (batch, ys)
    }
}

#[cfg(test)]
mod voronoi_tests {
    use super::*;

    #[test]
    fn labels_partition_by_nearest_marker() {
        let mut ds = VoronoiSeg::new(16, 0);
        let (img, labels) = ds.sample();
        assert_eq!(img.shape, vec![3, 16, 16]);
        assert_eq!(labels.len(), 256);
        // Both classes occur (markers are distinct and in-bounds).
        assert!(labels.iter().any(|&l| l == 0));
        assert!(labels.iter().any(|&l| l == 1));
        assert!(labels.iter().all(|&l| l == 0 || l == 1));
    }

    #[test]
    fn marker_pixels_carry_their_own_label() {
        // The brightest pixel of channel 0 is marker A -> label 0; of
        // channel 1 is marker B -> label 1.
        let mut ds = VoronoiSeg::new(24, 3);
        let (img, labels) = ds.sample();
        for (ch, want) in [(0usize, 0i32), (1, 1)] {
            let mut best = (0usize, f32::NEG_INFINITY);
            for i in 0..24 * 24 {
                let v = img.data[ch * 24 * 24 + i];
                if v > best.1 {
                    best = (i, v);
                }
            }
            assert_eq!(labels[best.0], want, "channel {ch} marker mislabeled");
        }
    }

    #[test]
    fn batch_shapes_and_determinism() {
        let (x1, y1) = VoronoiSeg::new(16, 9).batch(3);
        let (x2, y2) = VoronoiSeg::new(16, 9).batch(3);
        assert_eq!(x1.shape, vec![3, 3, 16, 16]);
        assert_eq!(y1.len(), 3 * 256);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }
}
