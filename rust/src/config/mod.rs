//! Typed configuration for the launcher: serve / train / sim sections with
//! defaults, loadable from a TOML file and overridable from CLI args.
//!
//! A downstream user drives the binary either entirely from flags or by
//! pointing `--config path.toml` at a file like:
//!
//! ```toml
//! [serve]
//! workers = 4
//! max_batch = 4
//! max_wait_us = 2000
//! # Workspace pool for the cpu-fused backend's scan scratch:
//! # retention cap (MiB) and whether buckets pre-warm at registration.
//! workspace_cap_mb = 512
//! workspace_prewarm = true
//! # SLO / overload policy: per-class latency budgets (µs; 0 = none)
//! # become default deadlines for requests that do not set one; a
//! # request whose deadline passes before execution is shed with a
//! # structured Deadline reply. slo_p99_us is the observed-latency
//! # target behind the rolling error budget: when more than
//! # slo_error_budget of the recent completions violate it — or the
//! # queue sits above shed_queue_frac of queue_cap — low-priority
//! # admissions are shed (structured Shed) until the overload clears.
//! slo_high_us = 0
//! slo_normal_us = 0
//! slo_low_us = 0
//! slo_p99_us = 0
//! slo_error_budget = 0.05
//! shed_queue_frac = 0.75
//! # Per-tenant token-bucket admission quota (0 rps = unlimited).
//! quota_rps = 0.0
//! quota_burst = 32
//! # Per-request workspace admission cap (MiB; 0 = none). Over-cap
//! # geometries are admitted only when tiling can bound their peak
//! # memory; otherwise they get a structured TooLarge reply.
//! max_request_mb = 0
//!
//! [train]
//! steps = 200
//! log_every = 10
//!
//! [sim]
//! device = "a100-sxm4-80gb"
//! ```

use crate::util::cli::Args;
use crate::util::toml::Toml;

#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Number of executor workers pulling batches. 0 (the default) means
    /// auto: the coordinator sizes the executor set off the shared
    /// `ThreadPool::global()` width, since executors fan their CPU work
    /// into that pool.
    pub workers: usize,
    /// Dynamic batcher: max requests fused into one executable call.
    pub max_batch: usize,
    /// Dynamic batcher: max time the head request waits for peers.
    pub max_wait_us: u64,
    /// Bounded-queue admission limit (requests). 0 = unbounded.
    pub queue_cap: usize,
    /// Release partial batches when a worker would otherwise idle.
    pub eager_idle: bool,
    /// Synthetic client: offered load in requests/second.
    pub rate_rps: f64,
    /// Synthetic client: total requests to send.
    pub requests: usize,
    /// Artifact directory.
    pub artifacts: String,
    /// Execution backend: "pjrt" (compiled HLO artifacts) or "cpu"
    /// (the fused pure-Rust scan engine; serves any geometry, no
    /// artifacts required).
    pub backend: String,
    pub seed: u64,
    /// Retention cap of the coordinator's workspace pool (MiB): scan
    /// scratch released over this total is dropped instead of pooled.
    /// 0 disables retention entirely (every release frees).
    pub workspace_cap_mb: usize,
    /// Pre-warm the workspace at bucket registration so even the first
    /// request of a bucket leases from the pool (cpu backend only).
    pub workspace_prewarm: bool,
    /// Default deadline budget (µs) for high-priority requests without
    /// an explicit deadline. 0 = no implicit deadline.
    pub slo_high_us: u64,
    /// Default deadline budget (µs) for normal-priority requests.
    pub slo_normal_us: u64,
    /// Default deadline budget (µs) for low-priority requests.
    pub slo_low_us: u64,
    /// Observed p99 latency target (µs) behind the rolling error
    /// budget. 0 disables latency-based shedding.
    pub slo_p99_us: u64,
    /// Error-budget threshold: shed low-priority traffic when more
    /// than this fraction of recent completions violated `slo_p99_us`.
    pub slo_error_budget: f64,
    /// Queue-depth shed watermark as a fraction of `queue_cap`: queued
    /// >= ceil(frac * cap) sheds low-priority admissions. <= 0 (or
    /// `queue_cap` 0) disables depth-based shedding.
    pub shed_queue_frac: f64,
    /// Per-tenant token-bucket refill rate (requests/second). 0 =
    /// quotas disabled.
    pub quota_rps: f64,
    /// Per-tenant token-bucket burst capacity (tokens).
    pub quota_burst: usize,
    /// Per-request workspace admission cap (MiB): a scan whose planned
    /// workspace footprint exceeds this is only admitted if tiling can
    /// bound its peak memory (auto-tiling against the workspace cap, or
    /// a forced tiled plan); with tiling disabled it is answered with a
    /// structured `RequestError::TooLarge` reply. 0 = no per-request
    /// cap.
    pub max_request_mb: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            max_batch: 4,
            max_wait_us: 2_000,
            queue_cap: 256,
            eager_idle: true,
            rate_rps: 200.0,
            requests: 500,
            artifacts: "artifacts".into(),
            backend: "pjrt".into(),
            seed: 0,
            workspace_cap_mb: 512,
            workspace_prewarm: true,
            slo_high_us: 0,
            slo_normal_us: 0,
            slo_low_us: 0,
            slo_p99_us: 0,
            slo_error_budget: 0.05,
            shed_queue_frac: 0.75,
            quota_rps: 0.0,
            quota_burst: 32,
            max_request_mb: 0,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub steps: usize,
    pub log_every: usize,
    pub eval_every: usize,
    pub artifacts: String,
    pub seed: u64,
    /// Which train-step artifact family ("classifier" | "attn_classifier").
    pub model: String,
    /// Synthetic dataset size (samples).
    pub dataset: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps: 200,
            log_every: 10,
            eval_every: 50,
            artifacts: "artifacts".into(),
            seed: 0,
            model: "classifier".into(),
            dataset: 512,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    pub device: String,
    pub out_dir: String,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self { device: "a100-sxm4-80gb".into(), out_dir: "bench_out".into() }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ScanConfig {
    /// Scan execution-planner override: `"auto"` (the cost-based
    /// planner decides), `"plane"`, `"segment"` (the two-phase
    /// decomposition under its production schedule — per-direction
    /// wavefront continuations with the carry correction fused into the
    /// scatter drain), `"dirfan"`, `"chained"` (the single-pass
    /// chained engine with decoupled look-back — bit-identical to
    /// `"segment"` at the same chunk count, no phase barrier), or
    /// `"tiled"` / `"tiled-chained"` (the bounded-memory streaming
    /// mode: row-band tiles around the auto-planned / chained inner
    /// engine, band height from `tile_band_rows`) — forces
    /// the named strategy wherever it is valid for the geometry.
    /// Applies to serving and the benches. `"auto"` defers to the
    /// `GSPN2_SCAN_PLAN` env var when that is set (the CI hook that
    /// exercises non-default strategies across the whole suite).
    pub plan: String,
    /// SIMD kernel override for the fused engine's inner loops:
    /// `"auto"` (detect once per process — AVX2 on x86_64, NEON on
    /// aarch64, scalar otherwise), `"scalar"`, `"avx2"`, or `"neon"`.
    /// Forcing a kernel the host does not support is an error at
    /// startup. Every vector kernel is pinned bit-identical to the
    /// scalar reference, so this knob moves throughput only. `"auto"`
    /// defers to the `GSPN2_SCAN_SIMD` env var when set (the CI hook
    /// that re-runs the scan suite under each kernel).
    pub simd: String,
    /// Storage precision for the staged tap panels and the chained
    /// engine's job-local panels: `"f32"` (bit-exact default) or
    /// `"bf16"` (half the staged working set; taps decode in the SIMD
    /// lanes, panel stores round to nearest even, every accumulation
    /// stays f32 — outputs match f32 to `(|f32| + 1)·2⁻⁶` elementwise).
    /// `"f32"` defers to the `GSPN2_SCAN_PRECISION` env var when set.
    pub precision: String,
    /// Row-band height (canonical columns per band) of the tiled
    /// streaming mode — used when the planner auto-tiles an over-cap
    /// geometry or when `plan` forces `"tiled"`/`"tiled-chained"`.
    /// 0 (the default) defers to the `GSPN2_SCAN_TILE_BAND_ROWS` env
    /// var, then the engine default (128).
    pub tile_band_rows: usize,
}

impl Default for ScanConfig {
    fn default() -> Self {
        Self {
            plan: "auto".into(),
            simd: "auto".into(),
            precision: "f32".into(),
            tile_band_rows: 0,
        }
    }
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    pub serve: ServeConfig,
    pub train: TrainConfig,
    pub sim: SimConfig,
    pub scan: ScanConfig,
}

impl Config {
    /// Layered: defaults <- TOML file (if `--config`) <- CLI flags.
    pub fn from_args(args: &Args) -> Result<Config, String> {
        let mut cfg = Config::default();
        if let Some(path) = args.get("config") {
            cfg.apply_toml(&Toml::load(path)?);
        }
        cfg.apply_args(args);
        Ok(cfg)
    }

    pub fn apply_toml(&mut self, t: &Toml) {
        let s = &mut self.serve;
        s.workers = t.usize_or("serve.workers", s.workers);
        s.max_batch = t.usize_or("serve.max_batch", s.max_batch);
        s.max_wait_us = t.usize_or("serve.max_wait_us", s.max_wait_us as usize) as u64;
        s.queue_cap = t.usize_or("serve.queue_cap", s.queue_cap);
        s.eager_idle = t.bool_or("serve.eager_idle", s.eager_idle);
        s.rate_rps = t.f64_or("serve.rate_rps", s.rate_rps);
        s.requests = t.usize_or("serve.requests", s.requests);
        s.artifacts = t.str_or("serve.artifacts", &s.artifacts);
        s.backend = t.str_or("serve.backend", &s.backend);
        s.seed = t.usize_or("serve.seed", s.seed as usize) as u64;
        s.workspace_cap_mb = t.usize_or("serve.workspace_cap_mb", s.workspace_cap_mb);
        s.workspace_prewarm = t.bool_or("serve.workspace_prewarm", s.workspace_prewarm);
        s.slo_high_us = t.usize_or("serve.slo_high_us", s.slo_high_us as usize) as u64;
        s.slo_normal_us = t.usize_or("serve.slo_normal_us", s.slo_normal_us as usize) as u64;
        s.slo_low_us = t.usize_or("serve.slo_low_us", s.slo_low_us as usize) as u64;
        s.slo_p99_us = t.usize_or("serve.slo_p99_us", s.slo_p99_us as usize) as u64;
        s.slo_error_budget = t.f64_or("serve.slo_error_budget", s.slo_error_budget);
        s.shed_queue_frac = t.f64_or("serve.shed_queue_frac", s.shed_queue_frac);
        s.quota_rps = t.f64_or("serve.quota_rps", s.quota_rps);
        s.quota_burst = t.usize_or("serve.quota_burst", s.quota_burst);
        s.max_request_mb = t.usize_or("serve.max_request_mb", s.max_request_mb);

        let tr = &mut self.train;
        tr.steps = t.usize_or("train.steps", tr.steps);
        tr.log_every = t.usize_or("train.log_every", tr.log_every);
        tr.eval_every = t.usize_or("train.eval_every", tr.eval_every);
        tr.artifacts = t.str_or("train.artifacts", &tr.artifacts);
        tr.seed = t.usize_or("train.seed", tr.seed as usize) as u64;
        tr.model = t.str_or("train.model", &tr.model);
        tr.dataset = t.usize_or("train.dataset", tr.dataset);

        self.sim.device = t.str_or("sim.device", &self.sim.device);
        self.sim.out_dir = t.str_or("sim.out_dir", &self.sim.out_dir);

        self.scan.plan = t.str_or("scan.plan", &self.scan.plan);
        self.scan.simd = t.str_or("scan.simd", &self.scan.simd);
        self.scan.precision = t.str_or("scan.precision", &self.scan.precision);
        self.scan.tile_band_rows =
            t.usize_or("scan.tile_band_rows", self.scan.tile_band_rows);
    }

    pub fn apply_args(&mut self, a: &Args) {
        let s = &mut self.serve;
        s.workers = a.usize_or("workers", s.workers);
        s.max_batch = a.usize_or("max-batch", s.max_batch);
        s.max_wait_us = a.u64_or("max-wait-us", s.max_wait_us);
        s.queue_cap = a.usize_or("queue-cap", s.queue_cap);
        if a.flag("no-eager-idle") {
            s.eager_idle = false;
        }
        s.rate_rps = a.f64_or("rate", s.rate_rps);
        s.requests = a.usize_or("requests", s.requests);
        s.artifacts = a.str_or("artifacts", &s.artifacts);
        s.backend = a.str_or("backend", &s.backend);
        s.seed = a.u64_or("seed", s.seed);
        s.workspace_cap_mb = a.usize_or("workspace-cap-mb", s.workspace_cap_mb);
        if a.flag("no-workspace-prewarm") {
            s.workspace_prewarm = false;
        }
        s.slo_high_us = a.u64_or("slo-high-us", s.slo_high_us);
        s.slo_normal_us = a.u64_or("slo-normal-us", s.slo_normal_us);
        s.slo_low_us = a.u64_or("slo-low-us", s.slo_low_us);
        s.slo_p99_us = a.u64_or("slo-p99-us", s.slo_p99_us);
        s.slo_error_budget = a.f64_or("slo-error-budget", s.slo_error_budget);
        s.shed_queue_frac = a.f64_or("shed-queue-frac", s.shed_queue_frac);
        s.quota_rps = a.f64_or("quota-rps", s.quota_rps);
        s.quota_burst = a.usize_or("quota-burst", s.quota_burst);
        s.max_request_mb = a.usize_or("max-request-mb", s.max_request_mb);

        let tr = &mut self.train;
        tr.steps = a.usize_or("steps", tr.steps);
        tr.log_every = a.usize_or("log-every", tr.log_every);
        tr.eval_every = a.usize_or("eval-every", tr.eval_every);
        tr.artifacts = a.str_or("artifacts", &tr.artifacts);
        tr.seed = a.u64_or("seed", tr.seed);
        tr.model = a.str_or("model", &tr.model);
        tr.dataset = a.usize_or("dataset", tr.dataset);

        self.sim.device = a.str_or("device", &self.sim.device);
        self.sim.out_dir = a.str_or("out-dir", &self.sim.out_dir);

        self.scan.plan = a.str_or("scan-plan", &self.scan.plan);
        self.scan.simd = a.str_or("scan-simd", &self.scan.simd);
        self.scan.precision = a.str_or("scan-precision", &self.scan.precision);
        self.scan.tile_band_rows =
            a.usize_or("scan-tile-band-rows", self.scan.tile_band_rows);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_without_flags() {
        let cfg = Config::from_args(&args(&[])).unwrap();
        assert_eq!(cfg, Config::default());
    }

    #[test]
    fn backend_from_toml_and_cli() {
        let t = Toml::parse("[serve]\nbackend = \"cpu\"\n").unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.serve.backend, "pjrt");
        cfg.apply_toml(&t);
        assert_eq!(cfg.serve.backend, "cpu");
        cfg.apply_args(&args(&["--backend", "pjrt"]));
        assert_eq!(cfg.serve.backend, "pjrt");
    }

    #[test]
    fn cli_overrides() {
        let cfg =
            Config::from_args(&args(&["--workers", "8", "--steps", "50", "--rate=99.5"]))
                .unwrap();
        assert_eq!(cfg.serve.workers, 8);
        assert_eq!(cfg.train.steps, 50);
        assert_eq!(cfg.serve.rate_rps, 99.5);
        assert_eq!(cfg.serve.max_batch, ServeConfig::default().max_batch);
    }

    #[test]
    fn toml_then_cli_layering() {
        let t = Toml::parse("[serve]\nworkers = 6\nmax_batch = 16\n").unwrap();
        let mut cfg = Config::default();
        cfg.apply_toml(&t);
        assert_eq!(cfg.serve.workers, 6);
        assert_eq!(cfg.serve.max_batch, 16);
        cfg.apply_args(&args(&["--workers", "2"]));
        assert_eq!(cfg.serve.workers, 2); // CLI wins
        assert_eq!(cfg.serve.max_batch, 16); // TOML preserved
    }

    #[test]
    fn workspace_knobs_from_toml_and_cli() {
        let t = Toml::parse("[serve]\nworkspace_cap_mb = 64\nworkspace_prewarm = false\n")
            .unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.serve.workspace_cap_mb, 512);
        assert!(cfg.serve.workspace_prewarm);
        cfg.apply_toml(&t);
        assert_eq!(cfg.serve.workspace_cap_mb, 64);
        assert!(!cfg.serve.workspace_prewarm);
        let cfg = Config::from_args(&args(&[
            "--workspace-cap-mb",
            "128",
            "--no-workspace-prewarm",
        ]))
        .unwrap();
        assert_eq!(cfg.serve.workspace_cap_mb, 128);
        assert!(!cfg.serve.workspace_prewarm);
    }

    #[test]
    fn slo_and_quota_knobs_from_toml_and_cli() {
        let t = Toml::parse(
            "[serve]\nslo_p99_us = 20000\nslo_low_us = 2000\nquota_rps = 50.5\nshed_queue_frac = 0.5\n",
        )
        .unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.serve.slo_p99_us, 0);
        assert_eq!(cfg.serve.quota_rps, 0.0);
        assert_eq!(cfg.serve.quota_burst, 32);
        assert_eq!(cfg.serve.slo_error_budget, 0.05);
        cfg.apply_toml(&t);
        assert_eq!(cfg.serve.slo_p99_us, 20_000);
        assert_eq!(cfg.serve.slo_low_us, 2_000);
        assert_eq!(cfg.serve.quota_rps, 50.5);
        assert_eq!(cfg.serve.shed_queue_frac, 0.5);
        let cfg = Config::from_args(&args(&[
            "--slo-high-us",
            "500",
            "--quota-rps=10",
            "--quota-burst",
            "8",
            "--slo-error-budget=0.1",
        ]))
        .unwrap();
        assert_eq!(cfg.serve.slo_high_us, 500);
        assert_eq!(cfg.serve.quota_rps, 10.0);
        assert_eq!(cfg.serve.quota_burst, 8);
        assert_eq!(cfg.serve.slo_error_budget, 0.1);
    }

    #[test]
    fn missing_config_file_errors() {
        let err = Config::from_args(&args(&["--config", "/no/such/file.toml"]));
        assert!(err.is_err());
    }

    #[test]
    fn scan_plan_from_toml_and_cli() {
        let t = Toml::parse("[scan]\nplan = \"segment\"\n").unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.scan.plan, "auto");
        cfg.apply_toml(&t);
        assert_eq!(cfg.scan.plan, "segment");
        cfg.apply_args(&args(&["--scan-plan", "dirfan"]));
        assert_eq!(cfg.scan.plan, "dirfan"); // CLI wins
        let cfg = Config::from_args(&args(&["--scan-plan", "plane"])).unwrap();
        assert_eq!(cfg.scan.plan, "plane");
        let cfg = Config::from_args(&args(&["--scan-plan", "chained"])).unwrap();
        assert_eq!(cfg.scan.plan, "chained");
    }

    #[test]
    fn tiling_knobs_from_toml_and_cli() {
        let t = Toml::parse(
            "[serve]\nmax_request_mb = 256\n[scan]\nplan = \"tiled\"\ntile_band_rows = 64\n",
        )
        .unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.serve.max_request_mb, 0);
        assert_eq!(cfg.scan.tile_band_rows, 0);
        cfg.apply_toml(&t);
        assert_eq!(cfg.serve.max_request_mb, 256);
        assert_eq!(cfg.scan.plan, "tiled");
        assert_eq!(cfg.scan.tile_band_rows, 64);
        cfg.apply_args(&args(&[
            "--max-request-mb",
            "128",
            "--scan-tile-band-rows=32",
            "--scan-plan",
            "tiled-chained",
        ]));
        assert_eq!(cfg.serve.max_request_mb, 128); // CLI wins
        assert_eq!(cfg.scan.tile_band_rows, 32);
        assert_eq!(cfg.scan.plan, "tiled-chained");
    }

    #[test]
    fn scan_simd_and_precision_from_toml_and_cli() {
        let t = Toml::parse("[scan]\nsimd = \"scalar\"\nprecision = \"bf16\"\n").unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.scan.simd, "auto");
        assert_eq!(cfg.scan.precision, "f32");
        cfg.apply_toml(&t);
        assert_eq!(cfg.scan.simd, "scalar");
        assert_eq!(cfg.scan.precision, "bf16");
        cfg.apply_args(&args(&["--scan-simd", "avx2", "--scan-precision", "f32"]));
        assert_eq!(cfg.scan.simd, "avx2"); // CLI wins
        assert_eq!(cfg.scan.precision, "f32");
        let cfg = Config::from_args(&args(&["--scan-simd", "neon"])).unwrap();
        assert_eq!(cfg.scan.simd, "neon");
        assert_eq!(cfg.scan.precision, "f32");
    }
}
