//! `gspn2` — the GSPN-2 launcher.
//!
//! ```text
//! gspn2 repro <id|all> [--device a100] [--out-dir bench_out] [--proxy-steps N]
//! gspn2 serve  [--workers N] [--max-batch N] [--max-wait-us U]
//!              [--rate RPS] [--requests N] [--artifacts DIR]
//! gspn2 train  [--model classifier|attn_classifier] [--steps N]
//!              [--log-every N] [--eval-every N] [--seed S]
//! gspn2 denoise-train [--steps N]
//! gspn2 seg-train [--steps N] [--eval-every N]
//! gspn2 sim    [--batch N] [--channels C] [--res R] [--proxy RATIO]
//! gspn2 info   [--artifacts DIR]
//! ```
//!
//! Any command also accepts `--config path.toml` (see `configs/`),
//! `--scan-plan auto|plane|segment|dirfan|chained|tiled|tiled-chained`
//! (the scan execution-planner override, `[scan] plan` in TOML),
//! `--scan-simd auto|scalar|avx2|neon` (the fused engine's lane-kernel
//! override, `[scan] simd`), `--scan-precision f32|bf16` (staged
//! panel storage precision, `[scan] precision`),
//! `--scan-tile-band-rows N` (row-band height of the tiled streaming
//! mode, `[scan] tile_band_rows`), and `--max-request-mb N` (serving
//! per-request workspace admission cap, `[serve] max_request_mb`).

use gspn2::config::Config;
use gspn2::coordinator::{Coordinator, SubmitError};
use gspn2::gpusim::{simulate, DeviceSpec, KernelConfig, ScanWorkload};
use gspn2::runtime::{Engine, Manifest};
use gspn2::train::{train_classifier, train_denoiser, train_segmenter};
use gspn2::util::cli::Args;
use gspn2::util::logging;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(raw);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            logging::error("gspn2", &format!("{e:#}"));
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> anyhow::Result<()> {
    let cfg = Config::from_args(args).map_err(|e| anyhow::anyhow!(e))?;
    // Scan planner override (`--scan-plan` / `[scan] plan`): an explicit
    // setting pins every pooled scan in this process; the "auto" default
    // defers to the planner (and the GSPN2_SCAN_PLAN env hook).
    if cfg.scan.plan != "auto" {
        gspn2::scan::plan::set_plan_override(&cfg.scan.plan)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    // SIMD kernel override (`--scan-simd` / `[scan] simd`): an explicit
    // setting pins the lane kernel (and errors if the host lacks it);
    // "auto" keeps runtime detection (and the GSPN2_SCAN_SIMD env hook).
    if cfg.scan.simd != "auto" {
        gspn2::scan::set_simd_override(&cfg.scan.simd).map_err(|e| anyhow::anyhow!(e))?;
    }
    // Panel storage precision (`--scan-precision` / `[scan] precision`):
    // "f32" keeps the bit-exact default (and the GSPN2_SCAN_PRECISION
    // env hook); "bf16" halves the staged working set.
    if cfg.scan.precision != "f32" {
        gspn2::scan::set_precision_override(&cfg.scan.precision)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    // Tiled-band height (`--scan-tile-band-rows` / `[scan]
    // tile_band_rows`): 0 keeps the GSPN2_SCAN_TILE_BAND_ROWS env hook
    // and the engine default.
    if cfg.scan.tile_band_rows != 0 {
        gspn2::scan::set_tile_band_rows(cfg.scan.tile_band_rows)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    match cmd {
        "repro" => {
            let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            let dev = DeviceSpec::by_name(&cfg.sim.device)
                .ok_or_else(|| anyhow::anyhow!("unknown device '{}'", cfg.sim.device))?;
            let proxy_steps = args.usize_or("proxy-steps", 60);
            gspn2::repro::run(id, &dev, &cfg.sim.out_dir, proxy_steps)
        }
        "serve" => serve(&cfg),
        "train" => {
            let engine = Engine::cpu(&cfg.train.artifacts)?;
            let report = train_classifier(
                &engine,
                &cfg.train.model,
                cfg.train.steps,
                cfg.train.log_every,
                cfg.train.eval_every,
                cfg.train.seed,
            )?;
            let path = format!("{}/loss_curve_{}.csv", cfg.sim.out_dir, cfg.train.model);
            std::fs::create_dir_all(&cfg.sim.out_dir)?;
            std::fs::write(&path, report.to_csv())?;
            println!(
                "trained {} for {} steps: loss {:.4}, eval acc {:.1}%, {:.1}s \
                 (driver overhead {:.1}%); curve -> {path}",
                cfg.train.model,
                cfg.train.steps,
                report.final_train_loss,
                report.final_eval_acc * 100.0,
                report.wall_s,
                report.step_overhead_frac * 100.0
            );
            Ok(())
        }
        "seg-train" => {
            let engine = Engine::cpu(&cfg.train.artifacts)?;
            let report = train_segmenter(
                &engine,
                cfg.train.steps,
                cfg.train.log_every,
                cfg.train.eval_every,
                cfg.train.seed,
            )?;
            let path = format!("{}/loss_curve_segmenter.csv", cfg.sim.out_dir);
            std::fs::create_dir_all(&cfg.sim.out_dir)?;
            std::fs::write(&path, report.to_csv())?;
            println!(
                "segmenter: {} steps, loss {:.4}, pixel acc {:.1}%; curve -> {path}",
                cfg.train.steps,
                report.final_train_loss,
                report.final_eval_acc * 100.0
            );
            Ok(())
        }
        "denoise-train" => {
            let engine = Engine::cpu(&cfg.train.artifacts)?;
            let report =
                train_denoiser(&engine, cfg.train.steps, cfg.train.log_every, cfg.train.seed)?;
            let path = format!("{}/loss_curve_denoiser.csv", cfg.sim.out_dir);
            std::fs::create_dir_all(&cfg.sim.out_dir)?;
            std::fs::write(&path, report.to_csv())?;
            println!(
                "denoiser: {} steps, final loss {:.4}; curve -> {path}",
                cfg.train.steps, report.final_train_loss
            );
            Ok(())
        }
        "sim" => {
            let dev = DeviceSpec::by_name(&cfg.sim.device)
                .ok_or_else(|| anyhow::anyhow!("unknown device '{}'", cfg.sim.device))?;
            let n = args.usize_or("batch", 16);
            let c = args.usize_or("channels", 8);
            let r = args.usize_or("res", 1024);
            let proxy = args.usize_or("proxy", 0);
            let wl = ScanWorkload::fwd(n, c, r, r);
            let g1 = simulate(&dev, &wl, &KernelConfig::gspn1());
            let kcfg =
                if proxy > 1 { KernelConfig::with_proxy(proxy) } else { KernelConfig::gspn2() };
            let g2 = simulate(&dev, &wl, &kcfg);
            println!("workload: {r}x{r} batch {n} channels {c} on {}", dev.name);
            println!(
                "  GSPN-1: {:8.3} ms  ({} launches, {:.0} GB/s, {:.1}% peak)",
                g1.time_ms, g1.launches, g1.achieved_gbs, g1.pct_peak
            );
            println!(
                "  GSPN-2: {:8.3} ms  ({} launches, {:.0} GB/s, {:.1}% peak)",
                g2.time_ms, g2.launches, g2.achieved_gbs, g2.pct_peak
            );
            println!("  speedup: {:.1}x", g1.time_ms / g2.time_ms);
            Ok(())
        }
        "info" => {
            let m = Manifest::load(&cfg.serve.artifacts)?;
            println!("artifacts in {}:", cfg.serve.artifacts);
            for e in &m.entries {
                println!(
                    "  {:<28} {:>3} inputs {:>3} outputs  kind={}",
                    e.name,
                    e.inputs.len(),
                    e.outputs.len(),
                    e.meta_str("kind").unwrap_or("-")
                );
            }
            Ok(())
        }
        _ => {
            println!(
                "gspn2 — GSPN-2 three-layer reproduction\n\n\
                 commands:\n  \
                 repro <id|all>   regenerate paper tables/figures ({})\n  \
                 serve            run the serving coordinator on a synthetic trace\n  \
                 train            train the classifier via PJRT artifacts\n  \
                 denoise-train    train the denoiser\n  \
                 sim              one-off kernel simulation\n  \
                 info             list compiled artifacts\n",
                gspn2::repro::ALL.join(", ")
            );
            Ok(())
        }
    }
}

fn serve(cfg: &Config) -> anyhow::Result<()> {
    use gspn2::coordinator::{generate_trace, TraceConfig};
    use std::time::Instant;

    let coord = Coordinator::start(&cfg.serve)?;
    let trace = generate_trace(&TraceConfig {
        rate_rps: cfg.serve.rate_rps,
        requests: cfg.serve.requests,
        seed: cfg.serve.seed,
        ..TraceConfig::default()
    });
    logging::info(
        "serve",
        &format!(
            "replaying {} requests at ~{:.0} rps over {} workers",
            trace.len(),
            cfg.serve.rate_rps,
            coord.worker_count()
        ),
    );
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rejected = 0usize;
    for ev in trace {
        let elapsed = t0.elapsed();
        if ev.at > elapsed {
            std::thread::sleep(ev.at - elapsed);
        }
        match coord.submit_scan(ev.x, ev.a_raw, ev.lam, 0) {
            Ok(rx) => pending.push(rx),
            Err(
                SubmitError::Backpressure | SubmitError::Shed | SubmitError::Quota(_),
            ) => rejected += 1,
            Err(e) => return Err(e.into()),
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        if let Ok(resp) = rx.recv() {
            if resp.result.is_ok() {
                ok += 1;
            }
        }
    }
    let metrics = coord.shutdown();
    println!("completed {ok} requests ({rejected} rejected at admission)\n");
    println!("{}", metrics.report());
    Ok(())
}
