//! Integration: the full AOT bridge. Loads the HLO-text artifacts built
//! by `python/compile/aot.py`, compiles them on the PJRT CPU client, and
//! checks numerics against the pure-Rust GSPN reference (`gspn2::scan`) —
//! two implementations that share no code, one lowered through
//! JAX/Pallas, one hand-written.
//!
//! Requires `make artifacts` (skips, loudly, when artifacts are absent).

use gspn2::runtime::{artifacts_available, Engine, Value};
use gspn2::scan::{scan_l2r, Taps};
use gspn2::util::Rng;
use gspn2::Tensor;

const DIR: &str = "artifacts";

fn engine() -> Option<Engine> {
    if !artifacts_available(DIR) {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::cpu(DIR).expect("engine"))
}

fn scan_case(
    engine: &Engine,
    name: &str,
    n: usize,
    c: usize,
    cw: usize,
    h: usize,
    w: usize,
    kchunk: usize,
    seed: u64,
) {
    let mut rng = Rng::new(seed);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let a_raw = Tensor::randn(&[n, cw, 3, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);

    let outs = engine
        .run(
            name,
            &[
                Value::F32(x.clone()),
                Value::F32(a_raw.clone()),
                Value::F32(lam.clone()),
            ],
        )
        .expect("execute");
    let got = outs[0].as_f32().unwrap();

    let taps = Taps::normalize(&a_raw);
    let want = scan_l2r(&x, &taps, &lam, kchunk);
    let diff = got.max_abs_diff(&want);
    assert!(
        diff < 2e-4,
        "{name}: PJRT vs Rust reference diverge by {diff}"
    );
}

#[test]
fn scan_artifact_matches_rust_reference() {
    let Some(e) = engine() else { return };
    scan_case(&e, "scan_h64w64c8n1", 1, 8, 1, 64, 64, 0, 0);
}

#[test]
fn scan_batched_artifacts_match() {
    let Some(e) = engine() else { return };
    scan_case(&e, "scan_h64w64c8n2", 2, 8, 1, 64, 64, 0, 1);
    scan_case(&e, "scan_h64w64c8n4", 4, 8, 1, 64, 64, 0, 2);
}

#[test]
fn scan_highres_artifact_matches() {
    let Some(e) = engine() else { return };
    scan_case(&e, "scan_h128w128c8n1", 1, 8, 1, 128, 128, 0, 3);
}

#[test]
fn scan_per_channel_artifact_matches() {
    let Some(e) = engine() else { return };
    scan_case(&e, "scan_h64w64c8n1pc", 1, 8, 8, 64, 64, 0, 4);
}

#[test]
fn scan_chunked_artifact_matches() {
    let Some(e) = engine() else { return };
    scan_case(&e, "scan_h64w64c8n1k16", 1, 8, 1, 64, 64, 16, 5);
}

#[test]
fn executable_cache_hits() {
    let Some(e) = engine() else { return };
    let _ = e.load("scan_h64w64c8n1").unwrap();
    let compiles_before = e.stats.borrow().compiles;
    let _ = e.load("scan_h64w64c8n1").unwrap();
    assert_eq!(e.stats.borrow().compiles, compiles_before, "cache miss");
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(e) = engine() else { return };
    let bad = vec![
        Value::F32(Tensor::zeros(&[1, 8, 64, 63])), // wrong W
        Value::F32(Tensor::zeros(&[1, 1, 3, 64, 64])),
        Value::F32(Tensor::zeros(&[1, 8, 64, 64])),
    ];
    assert!(e.run("scan_h64w64c8n1", &bad).is_err());
    let too_few = vec![Value::F32(Tensor::zeros(&[1, 8, 64, 64]))];
    assert!(e.run("scan_h64w64c8n1", &too_few).is_err());
}

#[test]
fn classifier_fwd_produces_logits() {
    let Some(e) = engine() else { return };
    let mut inputs = e.initial_params("classifier_fwd_b8").unwrap();
    let mut rng = Rng::new(9);
    inputs.push(Value::F32(Tensor::randn(&[8, 3, 32, 32], &mut rng, 1.0)));
    let outs = e.run("classifier_fwd_b8", &inputs).unwrap();
    let logits = outs[0].as_f32().unwrap();
    assert_eq!(logits.shape, vec![8, 10]);
    assert!(logits.data.iter().all(|v| v.is_finite()));
    // Different inputs -> different logits (the model is not constant).
    let mut inputs2 = e.initial_params("classifier_fwd_b8").unwrap();
    inputs2.push(Value::F32(Tensor::randn(&[8, 3, 32, 32], &mut rng, 1.0)));
    let outs2 = e.run("classifier_fwd_b8", &inputs2).unwrap();
    assert!(logits.max_abs_diff(outs2[0].as_f32().unwrap()) > 1e-6);
}

#[test]
fn train_step_decreases_loss() {
    let Some(e) = engine() else { return };
    let entry = e.entry("classifier_train_b8").unwrap().clone();
    let k = entry.n_params;
    let params = e.initial_params("classifier_train_b8").unwrap();
    let mut rng = Rng::new(11);
    let x = Value::F32(Tensor::randn(&[8, 3, 32, 32], &mut rng, 1.0));
    let y = Value::i32_vec((0..8).map(|_| rng.below(10) as i32).collect());

    let mut cur: Vec<Value> = params.clone();
    let mut vel: Vec<Value> = params
        .iter()
        .map(|p| Value::F32(Tensor::zeros(p.shape())))
        .collect();
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..5 {
        let mut inputs = Vec::with_capacity(2 * k + 2);
        inputs.extend(cur.iter().cloned());
        inputs.extend(vel.iter().cloned());
        inputs.push(x.clone());
        inputs.push(y.clone());
        let mut out = e.run("classifier_train_b8", &inputs).unwrap();
        let loss = out.pop().unwrap().scalar().unwrap() as f64;
        vel = out.drain(k..).collect();
        cur = out;
        first.get_or_insert(loss);
        last = loss;
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.9,
        "loss did not decrease: {first} -> {last}"
    );
}

#[test]
fn denoiser_fwd_runs_both_resolutions() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(13);
    for (name, b, r) in [("denoiser_fwd_r16_b4", 4usize, 16usize), ("denoiser_fwd_r32_b1", 1, 32)] {
        let mut inputs = e.initial_params(name).unwrap();
        inputs.push(Value::F32(Tensor::randn(&[b, 3, r, r], &mut rng, 1.0)));
        inputs.push(Value::F32(Tensor::from_vec(
            &[b],
            (0..b).map(|i| i as f32 * 7.0).collect(),
        )));
        let outs = e.run(name, &inputs).unwrap();
        assert_eq!(outs[0].as_f32().unwrap().shape, vec![b, 3, r, r]);
    }
}

#[test]
fn attention_baseline_artifacts_run() {
    let Some(e) = engine() else { return };
    let mut inputs = e.initial_params("attn_classifier_fwd_b8").unwrap();
    let mut rng = Rng::new(17);
    inputs.push(Value::F32(Tensor::randn(&[8, 3, 32, 32], &mut rng, 1.0)));
    let outs = e.run("attn_classifier_fwd_b8", &inputs).unwrap();
    assert_eq!(outs[0].as_f32().unwrap().shape, vec![8, 10]);
}
