//! Failure injection: the runtime and coordinator must fail loudly and
//! cleanly — no hangs, no silent zeros — when the artifact store is
//! corrupt, requests are malformed, or the system is shut down.

use std::fs;
use std::path::PathBuf;
use std::time::Duration;

use gspn2::config::ServeConfig;
use gspn2::coordinator::{Coordinator, SubmitError};
use gspn2::runtime::{artifacts_available, Engine, Manifest, Value};
use gspn2::util::Rng;
use gspn2::Tensor;

const DIR: &str = "artifacts";

fn ready() -> bool {
    if !artifacts_available(DIR) {
        eprintln!("SKIP: artifacts/ not built");
        return false;
    }
    true
}

/// A scratch directory that cleans itself up.
struct Scratch(PathBuf);

impl Scratch {
    fn new(tag: &str) -> Scratch {
        let p = std::env::temp_dir().join(format!(
            "gspn2-failinj-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&p);
        fs::create_dir_all(&p).unwrap();
        Scratch(p)
    }

    fn path(&self) -> &str {
        self.0.to_str().unwrap()
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

// ---------------------------------------------------------------------------
// Artifact-store corruption
// ---------------------------------------------------------------------------

#[test]
fn engine_on_missing_dir_errors() {
    let err = match Engine::cpu("/nonexistent/gspn2-artifacts") {
        Ok(_) => panic!("engine started from a missing dir"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "unhelpful error: {msg}");
}

#[test]
fn engine_on_empty_dir_errors() {
    let s = Scratch::new("empty");
    assert!(!artifacts_available(s.path()));
    assert!(Engine::cpu(s.path()).is_err());
}

#[test]
fn corrupt_manifest_json_errors() {
    let s = Scratch::new("badjson");
    fs::write(s.0.join("manifest.json"), "{not json at all").unwrap();
    let err = Manifest::load(s.path()).unwrap_err();
    assert!(format!("{err:#}").contains("manifest"));
}

#[test]
fn manifest_without_entries_errors() {
    let s = Scratch::new("noentries");
    fs::write(s.0.join("manifest.json"), r#"{"version": 1}"#).unwrap();
    let err = Manifest::load(s.path()).unwrap_err();
    assert!(format!("{err:#}").contains("entries"));
}

#[test]
fn entry_missing_required_field_errors() {
    let s = Scratch::new("badentry");
    fs::write(
        s.0.join("manifest.json"),
        r#"{"entries": [{"file": "x.hlo.txt"}]}"#,
    )
    .unwrap();
    let err = Manifest::load(s.path()).unwrap_err();
    assert!(format!("{err:#}").contains("name"));
}

#[test]
fn missing_hlo_file_fails_at_load_not_at_startup() {
    if !ready() {
        return;
    }
    // Copy only the manifest (no .hlo.txt files): startup enumerates fine,
    // but loading any executable must produce a path-bearing error.
    let s = Scratch::new("nohlo");
    fs::copy(
        PathBuf::from(DIR).join("manifest.json"),
        s.0.join("manifest.json"),
    )
    .unwrap();
    let engine = Engine::cpu(s.path()).expect("engine starts from manifest alone");
    let name = engine.manifest().entries[0].name.clone();
    let err = match engine.load(&name) {
        Ok(_) => panic!("loaded an executable with no HLO file"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("hlo") || msg.contains("No such file"), "{msg}");
}

#[test]
fn truncated_params_bin_errors_with_sizes() {
    if !ready() {
        return;
    }
    let real = Manifest::load(DIR).unwrap();
    let entry = real
        .entries
        .iter()
        .find(|e| e.params_bin.is_some())
        .expect("some entry has params");
    // Rebuild the store with a truncated params.bin.
    let s = Scratch::new("truncparams");
    fs::copy(
        PathBuf::from(DIR).join("manifest.json"),
        s.0.join("manifest.json"),
    )
    .unwrap();
    let bin = entry.params_bin.clone().unwrap();
    let bytes = fs::read(PathBuf::from(DIR).join(&bin)).unwrap();
    fs::write(s.0.join(&bin), &bytes[..bytes.len() / 2]).unwrap();
    let m = Manifest::load(s.path()).unwrap();
    let e = m.get(&entry.name).unwrap();
    let err = m.load_params(e).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bytes"), "error should name the sizes: {msg}");
}

#[test]
fn garbage_hlo_text_fails_compile_not_panic() {
    if !ready() {
        return;
    }
    let s = Scratch::new("garbagehlo");
    fs::copy(
        PathBuf::from(DIR).join("manifest.json"),
        s.0.join("manifest.json"),
    )
    .unwrap();
    let m = Manifest::load(s.path()).unwrap();
    let entry = m.entries[0].clone();
    fs::write(s.0.join(&entry.file), "HloModule utterly_bogus\n???\n").unwrap();
    let engine = Engine::cpu(s.path()).unwrap();
    assert!(engine.load(&entry.name).is_err());
}

// ---------------------------------------------------------------------------
// Runtime request validation
// ---------------------------------------------------------------------------

#[test]
fn wrong_input_count_is_rejected() {
    if !ready() {
        return;
    }
    let engine = Engine::cpu(DIR).unwrap();
    let err = engine.run("scan_h64w64c8n1", &[Value::scalar_f32(1.0)]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("inputs"), "{msg}");
}

#[test]
fn wrong_dtype_is_rejected() {
    if !ready() {
        return;
    }
    let engine = Engine::cpu(DIR).unwrap();
    let mut rng = Rng::new(0);
    let x = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
    let a = Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0);
    // lam passed as i32 instead of f32.
    let lam = Value::i32_vec(vec![0; 1 * 8 * 64 * 64]);
    let err = engine
        .run("scan_h64w64c8n1", &[Value::F32(x), Value::F32(a), lam])
        .unwrap_err();
    let msg = format!("{err:#}").to_lowercase();
    assert!(msg.contains("dtype") || msg.contains("shape"), "{msg}");
}

#[test]
fn unknown_artifact_name_is_rejected() {
    if !ready() {
        return;
    }
    let engine = Engine::cpu(DIR).unwrap();
    let err = engine.run("scan_h1w1c1n1", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("scan_h1w1c1n1"));
}

// ---------------------------------------------------------------------------
// Coordinator failure paths
// ---------------------------------------------------------------------------

fn serve_cfg() -> ServeConfig {
    ServeConfig { workers: 1, max_batch: 4, max_wait_us: 200, queue_cap: 16, ..Default::default() }
}

#[test]
fn submit_after_shutdown_is_closed() {
    if !ready() {
        return;
    }
    let coord = Coordinator::start(&serve_cfg()).unwrap();
    let mut rng = Rng::new(2);
    let x = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
    let a = Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0);
    let lam = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
    // Take a second handle by value trick: shutdown consumes, so test the
    // flag through a pre-shutdown clone of the submit path instead —
    // start a second coordinator, shut it down, then submit.
    let metrics = coord.shutdown();
    assert_eq!(metrics.errors, 0);
    let coord2 = Coordinator::start(&serve_cfg()).unwrap();
    let rx = coord2.submit_scan(x, a, lam, 0);
    assert!(rx.is_ok());
    coord2.shutdown();
}

#[test]
fn direct_to_unknown_artifact_returns_error_response() {
    if !ready() {
        return;
    }
    let coord = Coordinator::start(&serve_cfg()).unwrap();
    let rx = coord.submit_direct("no_such_artifact", vec![]).expect("accepted");
    let resp = rx.recv_timeout(Duration::from_secs(60)).expect("worker replies");
    assert!(resp.result.is_err(), "expected an error response");
    let m = coord.shutdown();
    assert!(m.errors >= 1, "error not counted in metrics");
}

#[test]
fn direct_with_bad_inputs_returns_error_response() {
    if !ready() {
        return;
    }
    let coord = Coordinator::start(&serve_cfg()).unwrap();
    let rx = coord
        .submit_direct("scan_h64w64c8n1", vec![Value::scalar_f32(0.0)])
        .expect("accepted");
    let resp = rx.recv_timeout(Duration::from_secs(60)).expect("worker replies");
    assert!(resp.result.is_err());
    coord.shutdown();
}

#[test]
fn coordinator_on_corrupt_store_fails_fast() {
    let s = Scratch::new("coord-bad");
    fs::write(s.0.join("manifest.json"), "][").unwrap();
    let cfg = ServeConfig { artifacts: s.path().to_string(), ..serve_cfg() };
    assert!(Coordinator::start(&cfg).is_err());
}

#[test]
fn graceful_drain_completes_queued_work() {
    if !ready() {
        return;
    }
    // Queue several requests then immediately shut down: every response
    // channel must still resolve (drain, not drop).
    let coord = Coordinator::start(&serve_cfg()).unwrap();
    let mut rng = Rng::new(3);
    let mut rxs = Vec::new();
    for _ in 0..5 {
        let x = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
        let a = Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0);
        let lam = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
        rxs.push(coord.submit_scan(x, a, lam, 0).expect("submit"));
    }
    let metrics = coord.shutdown();
    let mut completed = 0;
    for rx in rxs {
        if let Ok(resp) = rx.recv_timeout(Duration::from_secs(1)) {
            assert!(resp.result.is_ok());
            completed += 1;
        }
    }
    assert_eq!(completed, 5, "drain dropped requests (metrics: {metrics:?})");
}

#[test]
fn backpressure_error_is_distinguishable() {
    if !ready() {
        return;
    }
    // queue_cap 1 with a slow drain: the second/third submit must be a
    // Backpressure error, not a hang or an UnknownBucket.
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait_us: 0,
        queue_cap: 1,
        ..Default::default()
    };
    let coord = Coordinator::start(&cfg).unwrap();
    let mut rng = Rng::new(4);
    let mut saw_backpressure = false;
    let mut rxs = Vec::new();
    for _ in 0..32 {
        let x = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
        let a = Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0);
        let lam = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
        match coord.submit_scan(x, a, lam, 0) {
            Ok(rx) => rxs.push(rx),
            Err(SubmitError::Backpressure) => {
                saw_backpressure = true;
                break;
            }
            Err(e) => panic!("unexpected error: {e:?}"),
        }
    }
    assert!(saw_backpressure, "queue_cap=1 never produced backpressure");
    coord.shutdown();
}
