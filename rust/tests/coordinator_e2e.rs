//! Integration: the serving coordinator end to end — submit scan
//! requests through the router/batcher/worker-pool and verify results
//! against the Rust reference, batching behaviour, backpressure, and
//! graceful drain.

use std::time::Duration;

use gspn2::config::ServeConfig;
use gspn2::coordinator::{Coordinator, Priority, RequestError, SubmitError, SubmitOptions};
use gspn2::runtime::artifacts_available;
use gspn2::scan::{scan_l2r, Taps};
use gspn2::util::Rng;
use gspn2::Tensor;

fn cfg(workers: usize, max_batch: usize, wait_us: u64, cap: usize) -> ServeConfig {
    ServeConfig {
        workers,
        max_batch,
        max_wait_us: wait_us,
        queue_cap: cap,
        ..ServeConfig::default()
    }
}

fn ready() -> bool {
    if !artifacts_available("artifacts") {
        eprintln!("SKIP: artifacts/ not built");
        return false;
    }
    true
}

fn mk_case(rng: &mut Rng, c: usize, h: usize, w: usize) -> (Tensor, Tensor, Tensor) {
    (
        Tensor::randn(&[1, c, h, w], rng, 1.0),
        Tensor::randn(&[1, 1, 3, h, w], rng, 1.0),
        Tensor::randn(&[1, c, h, w], rng, 1.0),
    )
}

#[test]
fn serves_correct_results() {
    if !ready() {
        return;
    }
    let coord = Coordinator::start(&cfg(1, 4, 500, 64)).unwrap();
    let mut rng = Rng::new(1);
    let mut cases = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..6 {
        let (x, a, lam) = mk_case(&mut rng, 8, 64, 64);
        let rx = coord
            .submit_scan(x.clone(), a.clone(), lam.clone(), 0)
            .expect("submit");
        cases.push((x, a, lam));
        rxs.push(rx);
    }
    for ((x, a, lam), rx) in cases.into_iter().zip(rxs) {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        let got = resp.result.expect("ok")[0].as_f32().unwrap().clone();
        let want = scan_l2r(&x, &Taps::normalize(&a), &lam, 0);
        assert!(
            got.max_abs_diff(&want) < 2e-4,
            "served result diverges: {}",
            got.max_abs_diff(&want)
        );
    }
    let m = coord.shutdown();
    assert_eq!(m.completed, 6);
    assert_eq!(m.errors, 0);
}

#[test]
fn batches_are_fused() {
    if !ready() {
        return;
    }
    // Long wait window so all requests land in one collection window.
    let coord = Coordinator::start(&cfg(1, 4, 50_000, 64)).unwrap();
    let mut rng = Rng::new(2);
    let mut rxs = Vec::new();
    for _ in 0..4 {
        let (x, a, lam) = mk_case(&mut rng, 8, 64, 64);
        rxs.push(coord.submit_scan(x, a, lam, 0).unwrap());
    }
    let mut max_batch_seen = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.result.is_ok());
        max_batch_seen = max_batch_seen.max(resp.batch);
    }
    assert!(
        max_batch_seen >= 2,
        "no fusion happened (max batch {max_batch_seen})"
    );
    let m = coord.shutdown();
    assert!(m.batch_sizes.mean() > 1.0);
}

#[test]
fn unknown_bucket_rejected() {
    if !ready() {
        return;
    }
    let coord = Coordinator::start(&cfg(1, 4, 500, 64)).unwrap();
    let mut rng = Rng::new(3);
    // 32x32 geometry has no compiled artifact.
    let (x, a, lam) = mk_case(&mut rng, 8, 32, 32);
    match coord.submit_scan(x, a, lam, 0) {
        Err(SubmitError::UnknownBucket(name)) => {
            assert!(name.contains("h32w32"), "{name}");
        }
        other => panic!("expected UnknownBucket, got {other:?}"),
    }
    coord.shutdown();
}

#[test]
fn backpressure_rejects_when_full() {
    if !ready() {
        return;
    }
    // Capacity 2, one slow worker, huge wait -> the queue fills.
    let coord = Coordinator::start(&cfg(1, 4, 2_000_000, 2)).unwrap();
    let mut rng = Rng::new(4);
    let mut kept = Vec::new();
    let mut saw_backpressure = false;
    for _ in 0..8 {
        let (x, a, lam) = mk_case(&mut rng, 8, 64, 64);
        match coord.submit_scan(x, a, lam, 0) {
            Ok(rx) => kept.push(rx),
            Err(SubmitError::Backpressure) => {
                saw_backpressure = true;
                break;
            }
            Err(e) => panic!("unexpected {e:?}"),
        }
    }
    assert!(saw_backpressure, "queue never filled");
    let m = coord.shutdown();
    assert!(m.rejected >= 1);
    // The admitted requests still complete during drain.
    for rx in kept {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r.result.is_ok());
    }
}

#[test]
fn multiple_buckets_served() {
    if !ready() {
        return;
    }
    let coord = Coordinator::start(&cfg(2, 4, 1_000, 64)).unwrap();
    let mut rng = Rng::new(5);
    let mut rxs = Vec::new();
    for i in 0..6 {
        let (c, h, w) = if i % 2 == 0 { (8, 64, 64) } else { (8, 128, 128) };
        let (x, a, lam) = mk_case(&mut rng, c, h, w);
        rxs.push(coord.submit_scan(x, a, lam, 0).unwrap());
    }
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(180)).unwrap();
        assert!(r.result.is_ok());
    }
    let m = coord.shutdown();
    assert_eq!(m.completed, 6);
}

#[test]
fn direct_requests_execute() {
    if !ready() {
        return;
    }
    let coord = Coordinator::start(&cfg(1, 4, 500, 64)).unwrap();
    // Drive the classifier forward through the direct path.
    use gspn2::runtime::{Engine, Value};
    let engine = Engine::cpu("artifacts").unwrap();
    let mut inputs = engine.initial_params("classifier_fwd_b8").unwrap();
    let mut rng = Rng::new(6);
    inputs.push(Value::F32(Tensor::randn(&[8, 3, 32, 32], &mut rng, 1.0)));
    let rx = coord.submit_direct("classifier_fwd_b8", inputs).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    let outs = resp.result.expect("direct ok");
    assert_eq!(outs[0].as_f32().unwrap().shape, vec![8, 10]);
    coord.shutdown();
}

#[test]
fn chunked_bucket_served() {
    if !ready() {
        return;
    }
    let coord = Coordinator::start(&cfg(1, 4, 500, 64)).unwrap();
    let mut rng = Rng::new(7);
    let (x, a, lam) = mk_case(&mut rng, 8, 64, 64);
    let rx = coord.submit_scan(x.clone(), a.clone(), lam.clone(), 16).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    let got = resp.result.unwrap()[0].as_f32().unwrap().clone();
    let want = scan_l2r(&x, &Taps::normalize(&a), &lam, 16);
    assert!(got.max_abs_diff(&want) < 2e-4);
    coord.shutdown();
}

// ---------------------------------------------------------------------
// cpu-fused backend: the column-staged fused scan engine serves directly,
// no artifacts required — these tests always run.
// ---------------------------------------------------------------------

fn cpu_cfg(workers: usize, max_batch: usize, wait_us: u64, cap: usize) -> ServeConfig {
    ServeConfig { backend: "cpu".into(), ..cfg(workers, max_batch, wait_us, cap) }
}

#[test]
fn cpu_backend_serves_bit_identical_results() {
    let coord = Coordinator::start(&cpu_cfg(2, 4, 500, 64)).unwrap();
    let mut rng = Rng::new(11);
    let mut cases = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..6 {
        // Arbitrary geometries, including ones no artifact covers.
        let (c, h, w) = [(8, 64, 64), (3, 17, 29), (1, 5, 40)][i % 3];
        let (x, a, lam) = mk_case(&mut rng, c, h, w);
        let rx = coord
            .submit_scan(x.clone(), a.clone(), lam.clone(), 0)
            .expect("cpu backend accepts any valid geometry");
        cases.push((x, a, lam));
        rxs.push(rx);
    }
    for ((x, a, lam), rx) in cases.into_iter().zip(rxs) {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        let got = resp.result.expect("ok")[0].as_f32().unwrap().clone();
        let want = scan_l2r(&x, &Taps::normalize(&a), &lam, 0);
        // The fused engine is pinned bit-identical to the reference.
        assert_eq!(got.data, want.data, "cpu-fused serving diverged");
    }
    let m = coord.shutdown();
    assert_eq!(m.completed, 6);
    assert_eq!(m.errors, 0);
}

#[test]
fn cpu_backend_serves_chunked_scans() {
    let coord = Coordinator::start(&cpu_cfg(1, 4, 500, 64)).unwrap();
    let mut rng = Rng::new(12);
    let (x, a, lam) = mk_case(&mut rng, 4, 32, 48);
    let rx = coord.submit_scan(x.clone(), a.clone(), lam.clone(), 16).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    let got = resp.result.unwrap()[0].as_f32().unwrap().clone();
    let want = scan_l2r(&x, &Taps::normalize(&a), &lam, 16);
    assert_eq!(got.data, want.data);
    coord.shutdown();
}

#[test]
fn cpu_backend_still_validates_admission() {
    let coord = Coordinator::start(&cpu_cfg(1, 4, 500, 64)).unwrap();
    let mut rng = Rng::new(13);
    let (x, a, lam) = mk_case(&mut rng, 4, 32, 48);
    // Bad kchunk must still be a structured rejection, not a panic.
    match coord.submit_scan(x, a, lam, 7) {
        Err(SubmitError::Invalid(why)) => assert!(why.contains("kchunk"), "{why}"),
        other => panic!("expected Invalid, got {other:?}"),
    }
    let m = coord.shutdown();
    assert_eq!(m.rejected, 1);
}

#[test]
fn cpu_backend_fuses_batches() {
    // Long wait window so requests land in one collection window; the
    // cpu path reports the fused batch size it was released with.
    // eager_idle off: cpu workers are ready instantly (no engine
    // compile), so an idle-release could otherwise race the submissions
    // and drain the first request as a batch of 1.
    let coord = Coordinator::start(&ServeConfig {
        eager_idle: false,
        ..cpu_cfg(1, 4, 50_000, 64)
    })
    .unwrap();
    let mut rng = Rng::new(14);
    let mut rxs = Vec::new();
    for _ in 0..4 {
        let (x, a, lam) = mk_case(&mut rng, 2, 16, 16);
        rxs.push(coord.submit_scan(x, a, lam, 0).unwrap());
    }
    let mut max_batch_seen = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.result.is_ok());
        max_batch_seen = max_batch_seen.max(resp.batch);
    }
    assert!(max_batch_seen >= 2, "no fusion happened (max batch {max_batch_seen})");
    coord.shutdown();
}

#[test]
fn cpu_backend_low_occupancy_segments_and_matches_reference() {
    // A single large-resolution request (one plane, 512 columns) — the
    // §5.1 occupancy collapse. The cpu backend's fused engine splits it
    // via the occupancy scheduler; the result must be exactly the
    // scan_l2r_split reference at the scheduler's chosen count (or
    // exactly scan_l2r when the host pool is too narrow to segment).
    use gspn2::scan::{auto_segments, scan_l2r_split};
    use gspn2::util::ThreadPool;
    let coord = Coordinator::start(&cpu_cfg(1, 4, 500, 64)).unwrap();
    let mut rng = Rng::new(15);
    let (x, a, lam) = mk_case(&mut rng, 1, 64, 512);
    let rx = coord.submit_scan(x.clone(), a.clone(), lam.clone(), 0).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    let got = resp.result.unwrap()[0].as_f32().unwrap().clone();
    let taps = Taps::normalize(&a);
    let want = match auto_segments(1, 512, ThreadPool::global().threads()) {
        Some(s) => scan_l2r_split(&x, &taps, &lam, s, 1),
        None => scan_l2r(&x, &taps, &lam, 0),
    };
    assert_eq!(got.data, want.data, "low-occupancy serving diverged from its reference");
    coord.shutdown();
}

#[test]
fn workers_zero_auto_sizes_off_global_pool() {
    use gspn2::util::ThreadPool;
    let coord = Coordinator::start(&cpu_cfg(0, 4, 500, 64)).unwrap();
    let expect = (ThreadPool::global().threads() / 2).clamp(1, 8);
    assert_eq!(coord.worker_count(), expect);
    let mut rng = Rng::new(16);
    let (x, a, lam) = mk_case(&mut rng, 2, 8, 8);
    let rx = coord.submit_scan(x, a, lam, 0).unwrap();
    assert!(rx.recv_timeout(Duration::from_secs(120)).unwrap().result.is_ok());
    coord.shutdown();
}

#[test]
fn cpu_backend_rejects_direct_requests() {
    let coord = Coordinator::start(&cpu_cfg(1, 4, 500, 64)).unwrap();
    let rx = coord.submit_direct("classifier_fwd_b8", vec![]).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    let err = resp.result.expect_err("direct needs pjrt");
    assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
    coord.shutdown();
}

#[test]
fn unknown_backend_rejected_at_start() {
    let bad = ServeConfig { backend: "tpu".into(), ..ServeConfig::default() };
    assert!(Coordinator::start(&bad).is_err());
}

// ---------------------------------------------------------------------
// Overload robustness: SLO-aware admission, shedding, quotas, and the
// shutdown drain — all on the cpu backend, no artifacts required.
// ---------------------------------------------------------------------

/// Sustained overload (tight-loop submission, far beyond one worker's
/// capacity) with mixed priorities: low traffic is shed at admission,
/// high traffic is never shed and never blows its (generous) deadline,
/// and every single admitted request resolves — success or a structured
/// typed error, zero hangs, zero panics.
#[test]
fn overload_sheds_low_never_high_and_everything_resolves() {
    let coord = Coordinator::start(&ServeConfig {
        backend: "cpu".into(),
        workers: 1,
        max_batch: 4,
        max_wait_us: 200,
        queue_cap: 16,
        shed_queue_frac: 0.5,
        slo_low_us: 2_000,
        slo_high_us: 10_000_000,
        ..ServeConfig::default()
    })
    .unwrap();
    let mut rng = Rng::new(40);
    let total = 240usize;
    let cases: Vec<_> = (0..total).map(|_| mk_case(&mut rng, 8, 64, 64)).collect();
    let mut rxs = Vec::new();
    let (mut shed_low, mut shed_other, mut backpressure) = (0u64, 0u64, 0u64);
    for (i, (x, a, lam)) in cases.into_iter().enumerate() {
        let priority = if i % 2 == 0 { Priority::High } else { Priority::Low };
        let opts = SubmitOptions { priority, ..Default::default() };
        match coord.submit_scan_with(x, a, lam, 0, opts) {
            Ok(rx) => rxs.push((priority, rx)),
            Err(SubmitError::Shed) => {
                if priority == Priority::Low {
                    shed_low += 1;
                } else {
                    shed_other += 1;
                }
            }
            Err(SubmitError::Backpressure) => backpressure += 1,
            Err(e) => panic!("unexpected admission error: {e:?}"),
        }
    }
    assert_eq!(shed_other, 0, "only low-priority traffic may be shed");
    assert!(shed_low > 0, "sustained overload must shed low-priority traffic");
    assert_eq!(
        rxs.len() as u64 + shed_low + backpressure,
        total as u64,
        "every submission is accounted for"
    );
    // Every admitted request resolves with a definite outcome.
    let mut high_deadline_misses = 0u64;
    for (priority, rx) in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(180))
            .expect("every admitted request must resolve — no hung receivers");
        if let Err(e) = resp.result {
            let typed = e
                .downcast_ref::<RequestError>()
                .copied()
                .unwrap_or_else(|| panic!("untyped error under overload: {e:#}"));
            assert_ne!(typed, RequestError::Shed, "admitted requests are never shed");
            if priority == Priority::High && typed == RequestError::Deadline {
                high_deadline_misses += 1;
            }
        }
    }
    assert_eq!(
        high_deadline_misses, 0,
        "high class must keep its 10 s latency budget at this depth-capped load"
    );
    let m = coord.shutdown();
    assert_eq!(m.class_shed[Priority::High.index()], 0);
    assert_eq!(m.class_expired[Priority::High.index()], 0);
    assert!(m.class_completed[Priority::High.index()] > 0);
    assert!(m.rej_shed >= shed_low);
}

/// Per-tenant token buckets: a tenant bursting past its quota gets the
/// structured `Quota` rejection while other tenants are untouched.
#[test]
fn overload_quota_rejects_heavy_tenant() {
    let coord = Coordinator::start(&ServeConfig {
        backend: "cpu".into(),
        workers: 1,
        max_batch: 4,
        max_wait_us: 200,
        queue_cap: 64,
        quota_rps: 0.001, // negligible refill within the test
        quota_burst: 3,
        ..ServeConfig::default()
    })
    .unwrap();
    let mut rng = Rng::new(41);
    let mut rxs = Vec::new();
    let mut quota_hits = 0u64;
    for _ in 0..6 {
        let (x, a, lam) = mk_case(&mut rng, 2, 8, 8);
        let opts = SubmitOptions { tenant: 7, ..Default::default() };
        match coord.submit_scan_with(x, a, lam, 0, opts) {
            Ok(rx) => rxs.push(rx),
            Err(SubmitError::Quota(t)) => {
                assert_eq!(t, 7, "the rejection names the offending tenant");
                quota_hits += 1;
            }
            Err(e) => panic!("unexpected admission error: {e:?}"),
        }
    }
    assert_eq!(rxs.len(), 3, "burst capacity admits exactly quota_burst requests");
    assert_eq!(quota_hits, 3);
    // A different tenant draws from its own bucket.
    let (x, a, lam) = mk_case(&mut rng, 2, 8, 8);
    let opts = SubmitOptions { tenant: 8, ..Default::default() };
    rxs.push(coord.submit_scan_with(x, a, lam, 0, opts).expect("fresh tenant admitted"));
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(120)).unwrap().result.is_ok());
    }
    let m = coord.shutdown();
    assert_eq!(m.rej_quota, 3);
    assert_eq!(m.completed, 4);
}

/// Graceful-drain guarantee: enqueue well past one batch, shut down,
/// and every receiver resolves — executed during the drain or answered
/// with the structured `Closed` reply. No receiver may hang.
#[test]
fn overload_shutdown_resolves_every_receiver() {
    let coord = Coordinator::start(&ServeConfig {
        backend: "cpu".into(),
        workers: 1,
        max_batch: 2,
        max_wait_us: 2_000_000,
        queue_cap: 64,
        eager_idle: false,
        ..ServeConfig::default()
    })
    .unwrap();
    let mut rng = Rng::new(42);
    let mut rxs = Vec::new();
    for _ in 0..12 {
        let (x, a, lam) = mk_case(&mut rng, 2, 8, 8);
        rxs.push(coord.submit_scan(x, a, lam, 0).unwrap());
    }
    let m = coord.shutdown();
    let mut completed = 0u64;
    for rx in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("every receiver must resolve across shutdown");
        match resp.result {
            Ok(_) => completed += 1,
            Err(e) => assert_eq!(
                e.downcast_ref::<RequestError>(),
                Some(&RequestError::Closed),
                "shutdown replies must be the structured Closed error: {e:#}"
            ),
        }
    }
    assert_eq!(completed, m.completed);
    assert_eq!(completed + m.closed, 12, "completed + closed accounts for every request");
}
