//! Integration: the Rust training driver over the AOT train-step
//! artifacts — loss decreases on the directional-context task and on the
//! denoising objective, entirely from Rust.

use gspn2::runtime::{artifacts_available, Engine};
use gspn2::train::{train_classifier, train_denoiser, DirectionalContext, Trainer};

fn ready() -> bool {
    if !artifacts_available("artifacts") {
        eprintln!("SKIP: artifacts/ not built");
        return false;
    }
    true
}

#[test]
fn classifier_loss_decreases() {
    if !ready() {
        return;
    }
    let engine = Engine::cpu("artifacts").unwrap();
    let report = train_classifier(&engine, "classifier", 40, 1, 0, 42).unwrap();
    // Stochastic fresh-batch training: compare early-window vs
    // late-window mean loss.
    let losses: Vec<f64> = report.curve.iter().map(|l| l.loss).collect();
    let early = losses[..8].iter().sum::<f64>() / 8.0;
    let late = losses[losses.len() - 8..].iter().sum::<f64>() / 8.0;
    assert!(
        late < early,
        "mean loss did not decrease over 40 steps: {early:.3} -> {late:.3}"
    );
}

#[test]
fn trainer_eval_counts_bounded() {
    if !ready() {
        return;
    }
    let engine = Engine::cpu("artifacts").unwrap();
    let trainer = Trainer::new(&engine, "classifier").unwrap();
    let b = trainer.batch_size();
    let mut ds = DirectionalContext::new(trainer.image_size(), 0);
    let (x, y) = ds.batch(b);
    let (loss, correct) = trainer.eval(x, y).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!(correct <= b);
}

#[test]
fn attention_baseline_also_trains() {
    if !ready() {
        return;
    }
    let engine = Engine::cpu("artifacts").unwrap();
    // 60 steps with wide early/late windows: enough for the slower-to-warm
    // attention baseline to show a robust downward trend regardless of the
    // synthetic-data RNG stream.
    let report = train_classifier(&engine, "attn_classifier", 60, 10, 0, 42).unwrap();
    let losses: Vec<f64> = report.curve.iter().map(|l| l.loss).collect();
    let k = losses.len() / 3;
    let early = losses[..k].iter().sum::<f64>() / k as f64;
    let late = losses[losses.len() - k..].iter().sum::<f64>() / k as f64;
    assert!(late < early, "attn mean loss {early:.3} -> {late:.3}");
}

#[test]
fn denoiser_trains() {
    if !ready() {
        return;
    }
    let engine = Engine::cpu("artifacts").unwrap();
    let report = train_denoiser(&engine, 10, 5, 7).unwrap();
    let first = report.curve.first().unwrap().loss;
    assert!(
        report.final_train_loss < first,
        "denoise loss {first} -> {}",
        report.final_train_loss
    );
}

#[test]
fn missing_model_is_an_error() {
    if !ready() {
        return;
    }
    let engine = Engine::cpu("artifacts").unwrap();
    assert!(Trainer::new(&engine, "nonexistent_model").is_err());
}

#[test]
fn segmenter_learns_voronoi_pixels() {
    if !ready() {
        return;
    }
    let engine = Engine::cpu("artifacts").unwrap();
    let report =
        gspn2::train::train_segmenter(&engine, 60, 20, 30, 7).expect("seg training runs");
    // Pixel CE must drop well below ln(2) and pixel accuracy must beat
    // chance (50%) decisively.
    assert!(
        report.final_train_loss < 0.6,
        "seg loss stuck at {}",
        report.final_train_loss
    );
    assert!(
        report.final_eval_acc > 0.65,
        "pixel acc {} barely above chance",
        report.final_eval_acc
    );
}
