//! In-tree stub of the `xla` PJRT wrapper crate, so the workspace builds
//! and tests fully offline (the real wrapper links libxla/PJRT and
//! cannot be vendored here).
//!
//! Two tiers of fidelity:
//!
//! * **Host-side [`Literal`]s are fully functional** — typed creation
//!   from untyped bytes, shape introspection, `to_vec`, tuples. The
//!   `runtime::Value` bridge round-trips through them in unit tests.
//! * **The PJRT client surface compiles but does not execute**:
//!   [`PjRtClient::cpu`] returns an error, so `Engine::cpu` fails
//!   cleanly and every artifact-gated test/bench/example skips itself
//!   (the artifact store is absent in this build anyway). Swapping this
//!   path dependency for the real wrapper restores execution without
//!   touching `gspn2` code.

use std::fmt;

#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str =
    "PJRT runtime unavailable: this build uses the in-tree xla stub (host-side \
     literals only); link the real xla wrapper to execute artifacts";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn size_in_bytes(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
        }
    }
}

/// Dense array shape: element type + dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Element types that can be read back out of a literal.
pub trait NativeType: Sized {
    const TY: ElementType;
    fn from_le_bytes(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes(b: [u8; 4]) -> f32 {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes(b: [u8; 4]) -> i32 {
        i32::from_le_bytes(b)
    }
}

/// A host-side literal: either a dense typed array or a tuple.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        let want = elems * ty.size_in_bytes();
        if data.len() != want {
            return Err(Error(format!(
                "literal data is {} bytes, shape {dims:?} of {ty:?} needs {want}",
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
            tuple: None,
        })
    }

    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::F32, dims: Vec::new(), data: Vec::new(), tuple: Some(elems) }
    }

    pub fn shape(&self) -> Result<Shape> {
        match &self.tuple {
            Some(elems) => Ok(Shape::Tuple(
                elems.iter().map(|e| e.shape()).collect::<Result<Vec<_>>>()?,
            )),
            None => Ok(Shape::Array(ArrayShape { dims: self.dims.clone(), ty: self.ty })),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("to_vec on a tuple literal".into()));
        }
        if self.ty != T::TY {
            return Err(Error(format!("literal is {:?}, requested {:?}", self.ty, T::TY)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.tuple {
            Some(elems) => Ok(elems.clone()),
            None => Err(Error("to_tuple on a non-tuple literal".into())),
        }
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error(format!("cannot parse {path}: {STUB_MSG}")))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(STUB_MSG.to_string()))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB_MSG.to_string()))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB_MSG.to_string()))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(STUB_MSG.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        match lit.shape().unwrap() {
            Shape::Array(a) => {
                assert_eq!(a.dims(), &[3]);
                assert_eq!(a.ty(), ElementType::F32);
            }
            other => panic!("expected array shape, got {other:?}"),
        }
    }

    #[test]
    fn literal_size_mismatch_rejected() {
        let r = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0u8; 4]);
        assert!(r.is_err());
    }

    #[test]
    fn wrong_type_readback_rejected() {
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[1, 0, 0, 0])
                .unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1]);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn tuple_literals() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .unwrap();
        let t = Literal::tuple(vec![a.clone()]);
        assert_eq!(t.to_tuple().unwrap(), vec![a]);
        assert!(t.to_vec::<f32>().is_err());
    }

    #[test]
    fn client_is_gated() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
