//! Minimal in-tree stand-in for the `anyhow` crate so the workspace
//! builds fully offline (nothing is fetched from a registry). Implements
//! exactly the surface `gspn2` uses:
//!
//! * [`Error`] — a message plus a context/cause chain. `{}` prints the
//!   outermost message, `{:#}` the full chain joined with `": "`, and
//!   `{:?}` an anyhow-style "Caused by:" listing.
//! * [`Result<T>`] with the error type defaulted.
//! * [`anyhow!`] / [`bail!`] macros (literal, single-expression, and
//!   format-args forms).
//! * The [`Context`] extension trait (`context` / `with_context`) on
//!   `Result`s whose error converts into [`Error`] — including every
//!   `std::error::Error` via the blanket `From`.
//! * Typed-payload downcasting for errors built through [`Error::new`]:
//!   [`Error::downcast_ref`] recovers the original value, so callers
//!   (the serving coordinator's structured `Deadline`/`Shed`/`Closed`
//!   replies) can match on the concrete error type instead of parsing
//!   the message string. Errors built from messages or via the blanket
//!   `From` carry no payload and downcast to `None`.
//!
//! Not implemented (unused here): backtraces, `ensure!`.

use std::any::Any;
use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: outermost message first, then its causes, plus an
/// optional typed payload (the concrete error `Error::new` was built
/// from) for downcasting.
pub struct Error {
    chain: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()], payload: None }
    }

    /// Build an error from a concrete `std::error::Error`, keeping the
    /// value itself for [`Error::downcast_ref`] alongside the rendered
    /// source chain.
    pub fn new<E>(e: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain, payload: Some(Box::new(e)) }
    }

    /// Wrap with an outer context message (innermost stays last; the
    /// typed payload, if any, rides along).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The typed payload, when this error was built via [`Error::new`]
    /// from a `T`.
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.payload.as_ref().and_then(|p| p.downcast_ref())
    }

    /// Whether the payload is a `T` (anyhow's `is`).
    pub fn is<T: 'static>(&self) -> bool {
        self.downcast_ref::<T>().is_some()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts, capturing its source chain. `Error` itself
// deliberately does not implement `std::error::Error` (same trick as the
// real anyhow) so this blanket impl cannot overlap the reflexive
// `From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain, payload: None }
    }
}

/// Extension methods to attach context to failing `Result`s.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn context_on_std_errors() {
        let r: Result<()> = io_fail().with_context(|| "reading manifest".to_string());
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("reading manifest") && msg.contains("gone"), "{msg}");
    }

    #[test]
    fn macros_cover_all_forms() {
        let a = anyhow!("plain");
        let n = 3;
        let b = anyhow!("got {n} things");
        let c = anyhow!("got {} things", 4);
        let d = anyhow!(String::from("owned"));
        assert_eq!(format!("{a}"), "plain");
        assert_eq!(format!("{b}"), "got 3 things");
        assert_eq!(format!("{c}"), "got 4 things");
        assert_eq!(format!("{d}"), "owned");

        fn bails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "nope 1");
    }

    #[test]
    fn typed_payload_downcasts() {
        #[derive(Debug, PartialEq)]
        struct Marker(u32);
        impl fmt::Display for Marker {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "marker {}", self.0)
            }
        }
        impl std::error::Error for Marker {}

        let e = Error::new(Marker(7));
        assert_eq!(format!("{e}"), "marker 7");
        assert!(e.is::<Marker>());
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(7)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());
        // Context wrapping keeps the payload.
        let e = e.context("outer");
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(7)));
        assert_eq!(format!("{e:#}"), "outer: marker 7");
        // Message-built and From-converted errors carry no payload.
        assert!(!Error::msg("plain").is::<Marker>());
        let from: Error = io_fail().unwrap_err().into();
        assert!(from.downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
