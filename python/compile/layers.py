"""L2 building blocks: the small neural-net layer zoo the GSPN models use.

Everything here is a pure function over explicit parameter pytrees (nested
dicts of jnp arrays) so the whole model lowers to a single HLO module with
no Python state. Initialisers live next to the apply functions and use a
numpy Generator so artifact builds are deterministic.

Layout convention is NCHW throughout (matching the paper and the Rust
tensor library).
"""

from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initialisation helpers
# ---------------------------------------------------------------------------


def _fan_in_normal(rng: np.random.Generator, shape, fan_in: int) -> np.ndarray:
    std = math.sqrt(2.0 / max(1, fan_in))
    return rng.normal(0.0, std, size=shape).astype(np.float32)


def init_conv(
    rng: np.random.Generator,
    cin: int,
    cout: int,
    k: int = 1,
    *,
    groups: int = 1,
    zero: bool = False,
) -> dict:
    """Conv params: weight (cout, cin//groups, k, k) + bias (cout,)."""
    shape = (cout, cin // groups, k, k)
    fan_in = (cin // groups) * k * k
    w = (
        np.zeros(shape, dtype=np.float32)
        if zero
        else _fan_in_normal(rng, shape, fan_in)
    )
    return {"w": jnp.asarray(w), "b": jnp.zeros((cout,), dtype=jnp.float32)}


def init_linear(rng: np.random.Generator, din: int, dout: int) -> dict:
    return {
        "w": jnp.asarray(_fan_in_normal(rng, (din, dout), din)),
        "b": jnp.zeros((dout,), dtype=jnp.float32),
    }


def init_norm(c: int) -> dict:
    return {"g": jnp.ones((c,), dtype=jnp.float32)}


# ---------------------------------------------------------------------------
# Apply functions
# ---------------------------------------------------------------------------


def conv2d(p: dict, x: jnp.ndarray, *, stride: int = 1, groups: int = 1) -> jnp.ndarray:
    """NCHW conv with SAME padding."""
    k = p["w"].shape[-1]
    pad = (k - 1) // 2
    y = jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
    )
    return y + p["b"][None, :, None, None]


def conv1x1(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return conv2d(p, x)


def dwconv3x3(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise 3x3 — the Local Perception Unit's workhorse."""
    return conv2d(p, x, groups=x.shape[1])


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"] + p["b"]


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Channel RMSNorm over NCHW (normalises the C axis per position)."""
    ms = jnp.mean(jnp.square(x), axis=1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * p["g"][None, :, None, None]


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x, approximate=True)


def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    """(N, C, H, W) -> (N, C)."""
    return jnp.mean(x, axis=(2, 3))


def init_register_readout(rng: np.random.Generator, c: int, k: int = 4) -> dict:
    """Register-token readout head (see §6 Limitations).

    The paper notes GSPN "lacks CLS and register tokens commonly used in
    Vision Transformers, limiting direct applicability as a drop-in
    attention replacement in models relying on summary tokens". This head
    closes that gap: `k` learnable register tokens cross-attend over the
    final spatial features (queries = registers, keys/values = projected
    pixels) and their mean is the summary ("CLS") vector. Because the
    attention is only (k x HW), it adds O(k*HW*C) — negligible next to
    the backbone — while giving downstream users the summary-token
    interface ViT pipelines expect.
    """
    return {
        "reg": _fan_in_normal(rng, (k, c), c),       # learnable registers
        "wk": init_linear(rng, c, c),                 # key projection
        "wv": init_linear(rng, c, c),                 # value projection
        "wo": init_linear(rng, c, c),                 # output projection
    }


def register_readout(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """(N, C, H, W) -> (N, C) summary via register-token cross-attention."""
    n, c, h, w = x.shape
    toks = x.reshape(n, c, h * w).transpose(0, 2, 1)        # (N, HW, C)
    keys = linear(p["wk"], toks)                             # (N, HW, C)
    vals = linear(p["wv"], toks)                             # (N, HW, C)
    q = p["reg"]                                             # (K, C)
    att = jnp.einsum("kc,nlc->nkl", q, keys) / jnp.sqrt(jnp.float32(c))
    att = jax.nn.softmax(att, axis=-1)                       # (N, K, HW)
    reg = jnp.einsum("nkl,nlc->nkc", att, vals)              # (N, K, C)
    out = linear(p["wo"], reg)                               # (N, K, C)
    return jnp.mean(out, axis=1)                             # (N, C)


def depth_to_space(x: jnp.ndarray, r: int) -> jnp.ndarray:
    """(N, C*r^2, H, W) -> (N, C, H*r, W*r) pixel shuffle (decoder upsample)."""
    n, crr, h, w = x.shape
    c = crr // (r * r)
    x = x.reshape(n, c, r, r, h, w)
    x = x.transpose(0, 1, 4, 2, 5, 3)  # (N, C, H, r, W, r)
    return x.reshape(n, c, h * r, w * r)


def timestep_embedding(t: jnp.ndarray, dim: int, max_period: float = 10_000.0) -> jnp.ndarray:
    """Sinusoidal timestep embedding, (N,) -> (N, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
