"""L1 baseline: the per-step GSPN-1 analog.

GSPN-1 launched one small CUDA kernel per propagation step (§3.3 of the
paper). The JAX analog of that structure is a `lax.scan` over columns where
every step is a handful of small element-wise XLA ops on (N, C, H) slabs —
the hidden state round-trips through the loop carry (the HBM analog) and
nothing is fused across steps. This module exists:

  * as a second, structurally different implementation to cross-check the
    fused Pallas kernel against (both must match ref.py), and
  * as the baseline whose step count / op structure feeds the GSPN-1 cost
    model in `rust/src/gpusim/` (one launch per step, no on-chip reuse).

Tap/tensor conventions match ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("kchunk",))
def gspn_naive(
    x: jnp.ndarray,
    a: jnp.ndarray,
    lam: jnp.ndarray,
    *,
    kchunk: int = 0,
) -> jnp.ndarray:
    """Per-step left-to-right scan (GSPN-1 structure).

    x   : (N, C, H, W)
    a   : (N, Cw, 3, H, W) normalised taps, Cw in {1, C}
    lam : (N, C, H, W)

    Semantically identical to kernels.gspn.gspn_fused.
    """
    n, c, hdim, wdim = x.shape
    k = kchunk if kchunk and kchunk > 0 else wdim
    if wdim % k != 0:
        raise ValueError(f"kchunk={k} must divide W={wdim}")

    # Move the scan axis (W) to the front: (W, N, C, H) / (W, N, Cw, 3, H).
    xs = jnp.moveaxis(x, -1, 0).astype(jnp.float32)
    lams = jnp.moveaxis(lam, -1, 0).astype(jnp.float32)
    avs = jnp.moveaxis(a, -1, 0).astype(jnp.float32)
    # Chunk reset mask: step i starts a new chunk iff i % k == 0.
    reset = (jnp.arange(wdim) % k) == 0

    def step(h, inp):
        xi, li, ai, ri = inp
        h = jnp.where(ri, jnp.zeros_like(h), h)
        a_up, a_ct, a_dn = ai[:, :, 0], ai[:, :, 1], ai[:, :, 2]
        zero = jnp.zeros(h.shape[:-1] + (1,), dtype=h.dtype)
        h_up = jnp.concatenate([zero, h[..., :-1]], axis=-1)
        h_dn = jnp.concatenate([h[..., 1:], zero], axis=-1)
        h_new = a_up * h_up + a_ct * h + a_dn * h_dn + li * xi
        return h_new, h_new

    h0 = jnp.zeros((n, c, hdim), dtype=jnp.float32)
    _, hs = jax.lax.scan(step, h0, (xs, lams, avs, reset))
    return jnp.moveaxis(hs, 0, -1).astype(x.dtype)
