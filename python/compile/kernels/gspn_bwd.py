"""L1: the fused GSPN backward pass as a single Pallas kernel.

The paper benchmarks backward as well as forward (Fig. 4 reports 25-49x
backward speedups), and GSPN-1's backward suffered the same per-step
micro-launch structure. This module is the GSPN-2-style *fused reverse
scan*: one `pallas_call`, the adjoint carry staged on-chip for the whole
kernel, contiguous column slabs.

Math. Forward (per channel, canonical left-to-right):

    h_i = W_i h_{i-1} + lam_i .* x_i        (W_i tridiagonal from taps a)

Given upstream gradients g_i = dL/dh_i, define the adjoint

    ghat_i = g_i + W_{i+1}^T ghat_{i+1}     (reverse scan, ghat_W = g_W)

Then
    dL/dx_i    = lam_i .* ghat_i
    dL/dlam_i  = x_i  .* ghat_i
    dL/da_up [r,i] = ghat_i[r] * h_{i-1}[r-1]
    dL/da_ct [r,i] = ghat_i[r] * h_{i-1}[r]
    dL/da_dn [r,i] = ghat_i[r] * h_{i-1}[r+1]

with h_{-1} = 0 (and per-chunk resets handled for free because each chunk
is its own grid program). W^T applied to a vector v reads

    (W^T v)[r] = a_up[r+1] v[r+1] + a_ct[r] v[r] + a_dn[r-1] v[r-1].

Channel-shared taps (Cw == 1) sum the tap gradient over channels; the
kernel always emits per-channel tap gradients and the wrapper reduces.

`gspn.py`'s ``gspn_scan`` ties this to the forward kernel via
``jax.custom_vjp`` so L2 models can be differentiated and the whole
train-step lowers to one HLO module.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_bwd_kernel(g_ref, a_ref, x_ref, lam_ref, h_ref,
                     dx_ref, da_ref, dlam_ref, *, width: int):
    """Kernel body: one (n, channel-group, chunk) program, reverse scan.

    Block shapes:
      g_ref, x_ref, lam_ref, h_ref, dx_ref, dlam_ref : (1, c_tile, H, K)
      a_ref  : (1, cw_tile, 3, H, K)   cw_tile in {1, c_tile}
      da_ref : (1, c_tile, 3, H, K)    per-channel tap grads (reduced
                                       outside when taps are shared)

    The adjoint carry (c_tile, H) stays on-chip for the entire reverse
    scan — the backward twin of the forward kernel's SRAM staging.
    """
    c_tile, hdim = g_ref.shape[1], g_ref.shape[2]

    def wt_apply(a_up, a_ct, a_dn, v):
        """(W^T v) for tridiagonal W given its taps, batched over c_tile."""
        zero = jnp.zeros((v.shape[0], 1), dtype=v.dtype)
        up_shift = jnp.concatenate([a_up[:, 1:] * v[:, 1:], zero], axis=1)
        dn_shift = jnp.concatenate([zero, a_dn[:, :-1] * v[:, :-1]], axis=1)
        return up_shift + a_ct * v + dn_shift

    def step(j, carry):
        i = width - 1 - j
        a_up = a_ref[0, :, 0, :, i].astype(jnp.float32)
        a_ct = a_ref[0, :, 1, :, i].astype(jnp.float32)
        a_dn = a_ref[0, :, 2, :, i].astype(jnp.float32)
        ghat = g_ref[0, :, :, i].astype(jnp.float32) + carry

        # h_{i-1}: previous forward output, zero at the chunk head.
        iprev = jnp.maximum(i - 1, 0)
        h_prev = jnp.where(
            i == 0,
            jnp.zeros((c_tile, hdim), dtype=jnp.float32),
            h_ref[0, :, :, iprev].astype(jnp.float32),
        )

        xi = x_ref[0, :, :, i].astype(jnp.float32)
        li = lam_ref[0, :, :, i].astype(jnp.float32)
        dx_ref[0, :, :, i] = (li * ghat).astype(dx_ref.dtype)
        dlam_ref[0, :, :, i] = (xi * ghat).astype(dlam_ref.dtype)

        zero = jnp.zeros((c_tile, 1), dtype=jnp.float32)
        hp_up = jnp.concatenate([zero, h_prev[:, :-1]], axis=1)  # h_{i-1}[r-1]
        hp_dn = jnp.concatenate([h_prev[:, 1:], zero], axis=1)   # h_{i-1}[r+1]
        da_ref[0, :, 0, :, i] = (ghat * hp_up).astype(da_ref.dtype)
        da_ref[0, :, 1, :, i] = (ghat * h_prev).astype(da_ref.dtype)
        da_ref[0, :, 2, :, i] = (ghat * hp_dn).astype(da_ref.dtype)

        return wt_apply(a_up, a_ct, a_dn, ghat)

    c0 = jnp.zeros((c_tile, hdim), dtype=jnp.float32)
    jax.lax.fori_loop(0, width, step, c0)


@functools.partial(jax.jit, static_argnames=("kchunk", "c_tile", "interpret"))
def gspn_fused_bwd(
    g: jnp.ndarray,
    x: jnp.ndarray,
    a: jnp.ndarray,
    lam: jnp.ndarray,
    h: jnp.ndarray,
    *,
    kchunk: int = 0,
    c_tile: int = 1,
    interpret: bool = True,
):
    """Fused reverse scan. Returns (dx, da, dlam) with da matching a's
    shape (channel-shared tap grads are summed over channels)."""
    n, c, hdim, wdim = x.shape
    cw = a.shape[1]
    if cw not in (1, c):
        raise ValueError(f"Cw must be 1 or C={c}, got {cw}")
    if c % c_tile != 0:
        raise ValueError(f"c_tile={c_tile} must divide C={c}")
    k = kchunk if kchunk and kchunk > 0 else wdim
    if wdim % k != 0:
        raise ValueError(f"kchunk={k} must divide W={wdim}")
    nchunks = wdim // k
    cw_tile = c_tile if cw == c else 1

    grid = (n, c // c_tile, nchunks)
    kernel = functools.partial(_scan_bwd_kernel, width=k)
    blk = pl.BlockSpec((1, c_tile, hdim, k), lambda ni, ci, ki: (ni, ci, 0, ki))
    a_spec = pl.BlockSpec(
        (1, cw_tile, 3, hdim, k),
        (lambda ni, ci, ki: (ni, ci, 0, 0, ki))
        if cw_tile == c_tile and cw == c
        else (lambda ni, ci, ki: (ni, 0, 0, 0, ki)),
    )
    da_spec = pl.BlockSpec(
        (1, c_tile, 3, hdim, k), lambda ni, ci, ki: (ni, ci, 0, 0, ki)
    )
    dx, da_pc, dlam = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk, a_spec, blk, blk, blk],
        out_specs=[blk, da_spec, blk],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((n, c, 3, hdim, wdim), jnp.float32),
            jax.ShapeDtypeStruct(lam.shape, lam.dtype),
        ],
        interpret=interpret,
    )(g, a, x, lam, h)

    da = jnp.sum(da_pc, axis=1, keepdims=True) if cw == 1 else da_pc
    return dx, da.astype(a.dtype), dlam
