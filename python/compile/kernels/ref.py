"""Pure-numpy oracle for the GSPN line-scan recurrence.

This module is the correctness ground truth for every other implementation
(the fused Pallas kernel, the per-step baseline, and the Rust `scan`
module). It is deliberately written in the most literal way possible —
materialising the tridiagonal propagation matrix ``w_i`` of Eq. (1) as a
dense ``H x H`` matrix and performing explicit matrix-vector products —
so that it shares no code (and no bugs) with the optimised paths.

Conventions (canonical left-to-right scan; see DESIGN.md §6):

  x     : (N, C, H, W)  input
  a_raw : (N, Cw, 3, H, W)  unnormalised tap logits, Cw == C (per-channel,
          GSPN-1 mode) or Cw == 1 (channel-shared, GSPN-2 mode).
          Tap 0 = "up" (connects to row r-1 of the previous column),
          tap 1 = "center" (row r), tap 2 = "down" (row r+1).
  lam   : (N, C, H, W)  per-pixel input scaling (Diag(lambda) in Eq. 1)

The recurrence over columns i = 0..W-1:

  h[..., 0] = lam[..., 0] * x[..., 0]
  h[..., i] = w_i @ h[..., i-1] + lam[..., i] * x[..., i]

where ``w_i`` is tridiagonal and **row-stochastic** (Stability-Context
Condition): row r of ``w_i`` holds (a_up[r], a_c[r], a_dn[r]) at columns
(r-1, r, r+1), with out-of-range taps masked *before* normalisation so
every row sums to exactly 1.
"""

from __future__ import annotations

import numpy as np


def normalize_taps(a_raw: np.ndarray) -> np.ndarray:
    """sigmoid + boundary-masked row normalisation -> row-stochastic taps.

    a_raw: (..., 3, H, W) logits. Returns same-shape array where, for each
    (row r, column i), the in-range taps sum to 1 and out-of-range taps
    (up at r=0, down at r=H-1) are exactly 0.
    """
    a = 1.0 / (1.0 + np.exp(-np.asarray(a_raw, dtype=np.float64)))
    h = a.shape[-2]
    mask = np.ones_like(a)
    mask[..., 0, 0, :] = 0.0  # "up" tap invalid at top row
    mask[..., 2, h - 1, :] = 0.0  # "down" tap invalid at bottom row
    a = a * mask
    denom = a.sum(axis=-3, keepdims=True)
    return a / denom


def tridiag_from_taps(a: np.ndarray) -> np.ndarray:
    """Materialise one dense tridiagonal matrix from taps of one column.

    a: (3, H) normalised taps for a single (n, c, column i).
    Returns W_i: (H, H) with W_i[r, r-1] = a[0, r], W_i[r, r] = a[1, r],
    W_i[r, r+1] = a[2, r].
    """
    h = a.shape[1]
    w = np.zeros((h, h), dtype=np.float64)
    for r in range(h):
        if r - 1 >= 0:
            w[r, r - 1] = a[0, r]
        w[r, r] = a[1, r]
        if r + 1 < h:
            w[r, r + 1] = a[2, r]
    return w


def gspn_scan_ref(
    x: np.ndarray,
    a_raw: np.ndarray,
    lam: np.ndarray,
    kchunk: int = 0,
) -> np.ndarray:
    """Reference left-to-right GSPN scan via dense tridiagonal matmuls.

    kchunk == 0 means global propagation (one chunk spanning all of W);
    kchunk > 0 resets the hidden state at every chunk boundary
    (the GSPN-local variant of §3.2).

    Returns h: (N, C, H, W) hidden states (the caller applies the output
    modulation u ⊙ h of Eq. 2).
    """
    x = np.asarray(x, dtype=np.float64)
    lam = np.asarray(lam, dtype=np.float64)
    n, c, hdim, wdim = x.shape
    cw = a_raw.shape[1]
    assert cw in (1, c), f"Cw must be 1 or C, got {cw}"
    a = normalize_taps(a_raw)

    chunk = kchunk if kchunk and kchunk > 0 else wdim
    out = np.zeros_like(x)
    for ni in range(n):
        for ci in range(c):
            cwi = ci if cw == c else 0
            h = np.zeros(hdim, dtype=np.float64)
            for i in range(wdim):
                if i % chunk == 0:
                    h = np.zeros(hdim, dtype=np.float64)
                w_i = tridiag_from_taps(a[ni, cwi, :, :, i])
                h = w_i @ h + lam[ni, ci, :, i] * x[ni, ci, :, i]
                out[ni, ci, :, i] = h
    return out


def gspn_expand_g(a_raw: np.ndarray, lam: np.ndarray, n: int, c: int) -> np.ndarray:
    """Expand the recurrence into the dense block lower-triangular G of Eq. 4.

    For a single (n, c): returns G (W*H, W*H) such that vec(h) = G vec(x),
    where vec stacks columns i = 0..W-1. Used to validate the
    linear-attention view: block (i, j) equals
    (prod_{k=j+1}^{i} w_k) @ Diag(lam_j) for j <= i, else 0.
    """
    a = normalize_taps(a_raw)
    cw = a_raw.shape[1]
    cwi = c if cw > 1 else 0
    hdim, wdim = lam.shape[-2], lam.shape[-1]
    ws = [tridiag_from_taps(a[n, cwi, :, :, i]) for i in range(wdim)]
    lams = [np.diag(lam[n, c, :, i].astype(np.float64)) for i in range(wdim)]
    g = np.zeros((wdim * hdim, wdim * hdim), dtype=np.float64)
    for i in range(wdim):
        for j in range(i + 1):
            block = lams[j]
            for k in range(j + 1, i + 1):
                block = ws[k] @ block
            g[i * hdim : (i + 1) * hdim, j * hdim : (j + 1) * hdim] = block
    return g


# ---------------------------------------------------------------------------
# Directional wrappers. All four directions are expressed by flipping /
# transposing around the canonical left-to-right scan, exactly as the
# Rust reference and the Pallas kernel wrapper do.
# ---------------------------------------------------------------------------

DIRECTIONS = ("l2r", "r2l", "t2b", "b2t")


def to_canonical(t: np.ndarray, direction: str) -> np.ndarray:
    """Reorient a (..., H, W) tensor so the requested scan direction
    becomes a left-to-right scan over the last axis."""
    if direction == "l2r":
        return t
    if direction == "r2l":
        return t[..., ::-1]
    if direction == "t2b":
        return np.swapaxes(t, -1, -2)
    if direction == "b2t":
        return np.swapaxes(t, -1, -2)[..., ::-1]
    raise ValueError(direction)


def from_canonical(t: np.ndarray, direction: str) -> np.ndarray:
    """Inverse of :func:`to_canonical`."""
    if direction == "l2r":
        return t
    if direction == "r2l":
        return t[..., ::-1]
    if direction == "t2b":
        return np.swapaxes(t, -1, -2)
    if direction == "b2t":
        return np.swapaxes(t[..., ::-1], -1, -2)
    raise ValueError(direction)


def gspn_scan_ref_dir(
    x: np.ndarray,
    a_raw: np.ndarray,
    lam: np.ndarray,
    direction: str = "l2r",
    kchunk: int = 0,
) -> np.ndarray:
    """Directional reference scan. ``a_raw`` is given in canonical
    orientation (taps over the scan's cross axis), i.e. the caller produces
    it *after* reorienting x — matching how the model computes taps from
    the reoriented feature map."""
    xc = to_canonical(x, direction)
    lamc = to_canonical(lam, direction)
    hc = gspn_scan_ref(xc, a_raw, lamc, kchunk=kchunk)
    return from_canonical(hc, direction)
