"""L1: the fused GSPN-2 line-scan as a single Pallas kernel.

This is the TPU re-think of the paper's single-kernel CUDA design (§4.1,
§4.3 of the paper). The mapping from the paper's CUDA concepts:

  CUDA thread block over (chunk, n, c)    ->  Pallas grid (n, c_group, chunk)
  one warp pinned per channel slice       ->  `c_tile` channels per program
                                              (the paper's 2D block / cSlice)
  shared-memory staging of h_{i-1}        ->  the scan carry lives in
                                              registers/VMEM for the whole
                                              kernel (never round-trips HBM)
  coalesced column accesses               ->  H is the minor (lane) axis of
                                              every block; each step reads a
                                              contiguous (c_tile, H) slab
  single fused kernel, inner column loop  ->  one `pallas_call` whose body
                                              runs the full fori_loop over W

The kernel MUST run with ``interpret=True`` on this CPU-only image: real
TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
Numerics are identical between interpret and compiled modes; TPU
performance is estimated analytically in DESIGN.md §8.

Tap/tensor conventions match ``ref.py`` (see its docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def normalize_taps(a_raw: jnp.ndarray) -> jnp.ndarray:
    """jnp twin of ref.normalize_taps: sigmoid + boundary-masked row
    normalisation. Guarantees the Stability-Context Condition (each
    tridiagonal row of w_i sums to exactly 1)."""
    a = jax.nn.sigmoid(a_raw)
    h = a.shape[-2]
    row = jnp.arange(h)
    up_ok = (row > 0)[:, None]  # (H, 1) broadcast over W
    dn_ok = (row < h - 1)[:, None]
    mask = jnp.stack(
        [
            jnp.broadcast_to(up_ok, a.shape[-2:]),
            jnp.ones(a.shape[-2:], dtype=bool),
            jnp.broadcast_to(dn_ok, a.shape[-2:]),
        ],
        axis=0,
    )
    a = jnp.where(mask, a, 0.0)
    return a / jnp.sum(a, axis=-3, keepdims=True)


def _scan_kernel(x_ref, a_ref, lam_ref, o_ref, *, width: int):
    """Kernel body: one (n, channel-group, chunk) program.

    Block shapes:
      x_ref, lam_ref, o_ref : (1, c_tile, H, K)
      a_ref                 : (1, cw_tile, 3, H, K)  cw_tile in {1, c_tile}

    The hidden-state carry ``h`` has shape (c_tile, H) and stays on-chip
    for the entire scan — this is the fused-kernel + SRAM-staging insight
    of the paper in Pallas form.
    """
    c_tile, hdim = x_ref.shape[1], x_ref.shape[2]

    def step(i, h):
        # Taps for this column; a channel-shared block (cw_tile == 1)
        # broadcasts over the c_tile axis.
        a_up = a_ref[0, :, 0, :, i]
        a_ct = a_ref[0, :, 1, :, i]
        a_dn = a_ref[0, :, 2, :, i]
        zero = jnp.zeros((h.shape[0], 1), dtype=h.dtype)
        h_up = jnp.concatenate([zero, h[:, :-1]], axis=1)  # h_{i-1}[r-1]
        h_dn = jnp.concatenate([h[:, 1:], zero], axis=1)  # h_{i-1}[r+1]
        xi = x_ref[0, :, :, i].astype(jnp.float32)
        li = lam_ref[0, :, :, i].astype(jnp.float32)
        h_new = (
            a_up.astype(jnp.float32) * h_up
            + a_ct.astype(jnp.float32) * h
            + a_dn.astype(jnp.float32) * h_dn
            + li * xi
        )
        o_ref[0, :, :, i] = h_new.astype(o_ref.dtype)
        return h_new

    h0 = jnp.zeros((c_tile, hdim), dtype=jnp.float32)
    jax.lax.fori_loop(0, width, step, h0)


@functools.partial(
    jax.jit, static_argnames=("kchunk", "c_tile", "interpret")
)
def gspn_fused(
    x: jnp.ndarray,
    a: jnp.ndarray,
    lam: jnp.ndarray,
    *,
    kchunk: int = 0,
    c_tile: int = 1,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused left-to-right GSPN scan (GSPN-2 single-kernel analog).

    x   : (N, C, H, W)
    a   : (N, Cw, 3, H, W) **already normalised** taps (row-stochastic);
          Cw == 1 selects channel-shared (compact) propagation, Cw == C
          per-channel (GSPN-1 semantics).
    lam : (N, C, H, W)
    kchunk : 0 = global scan; > 0 = GSPN-local with independent chunks.
    c_tile : channels per program — the paper's 2D-block `cSlice` knob.

    Returns hidden states h with x's shape and dtype (accumulation is f32).
    """
    n, c, hdim, wdim = x.shape
    cw = a.shape[1]
    if cw not in (1, c):
        raise ValueError(f"Cw must be 1 or C={c}, got {cw}")
    if c % c_tile != 0:
        raise ValueError(f"c_tile={c_tile} must divide C={c}")
    k = kchunk if kchunk and kchunk > 0 else wdim
    if wdim % k != 0:
        raise ValueError(f"kchunk={k} must divide W={wdim}")
    nchunks = wdim // k
    cw_tile = c_tile if cw == c else 1

    grid = (n, c // c_tile, nchunks)
    kernel = functools.partial(_scan_kernel, width=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, c_tile, hdim, k), lambda ni, ci, ki: (ni, ci, 0, ki)
            ),
            pl.BlockSpec(
                (1, cw_tile, 3, hdim, k),
                (lambda ni, ci, ki: (ni, ci, 0, 0, ki))
                if cw_tile == c_tile and cw == c
                else (lambda ni, ci, ki: (ni, 0, 0, 0, ki)),
            ),
            pl.BlockSpec(
                (1, c_tile, hdim, k), lambda ni, ci, ki: (ni, ci, 0, ki)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, c_tile, hdim, k), lambda ni, ci, ki: (ni, ci, 0, ki)
        ),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, a, lam)


# ---------------------------------------------------------------------------
# Differentiable wrapper: forward kernel + fused backward kernel (custom VJP)
# ---------------------------------------------------------------------------
#
# `pallas_call` is a primitive with no AD rule, so models that train through
# the scan use `gspn_scan`, which pairs the forward kernel with the fused
# reverse-scan kernel in gspn_bwd.py. The tap input `a` is the *normalised*
# tap tensor — normalize_taps is plain jnp, so sigmoid/masking/renorm
# gradients flow through ordinary JAX AD outside the kernel.


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def gspn_scan(x, a, lam, kchunk=0, c_tile=1, interpret=True):
    """Differentiable fused GSPN scan (canonical left-to-right).

    Same contract as :func:`gspn_fused`; additionally supports
    ``jax.grad`` via the fused backward kernel.
    """
    return gspn_fused(x, a, lam, kchunk=kchunk, c_tile=c_tile, interpret=interpret)


def _gspn_scan_fwd(x, a, lam, kchunk, c_tile, interpret):
    h = gspn_fused(x, a, lam, kchunk=kchunk, c_tile=c_tile, interpret=interpret)
    return h, (x, a, lam, h)


def _gspn_scan_bwd(kchunk, c_tile, interpret, res, g):
    from .gspn_bwd import gspn_fused_bwd

    x, a, lam, h = res
    dx, da, dlam = gspn_fused_bwd(
        g, x, a, lam, h, kchunk=kchunk, c_tile=c_tile, interpret=interpret
    )
    return dx, da, dlam


gspn_scan.defvjp(_gspn_scan_fwd, _gspn_scan_bwd)


# ---------------------------------------------------------------------------
# Directional wrappers (mirror ref.py's to/from_canonical).
# ---------------------------------------------------------------------------

DIRECTIONS = ("l2r", "r2l", "t2b", "b2t")


def to_canonical(t: jnp.ndarray, direction: str) -> jnp.ndarray:
    if direction == "l2r":
        return t
    if direction == "r2l":
        return jnp.flip(t, axis=-1)
    if direction == "t2b":
        return jnp.swapaxes(t, -1, -2)
    if direction == "b2t":
        return jnp.flip(jnp.swapaxes(t, -1, -2), axis=-1)
    raise ValueError(direction)


def from_canonical(t: jnp.ndarray, direction: str) -> jnp.ndarray:
    if direction == "l2r":
        return t
    if direction == "r2l":
        return jnp.flip(t, axis=-1)
    if direction == "t2b":
        return jnp.swapaxes(t, -1, -2)
    if direction == "b2t":
        return jnp.swapaxes(jnp.flip(t, axis=-1), -1, -2)
    raise ValueError(direction)


def gspn_scan_dir(
    x: jnp.ndarray,
    a_raw: jnp.ndarray,
    lam: jnp.ndarray,
    direction: str = "l2r",
    *,
    kchunk: int = 0,
    c_tile: int = 1,
    interpret: bool = True,
) -> jnp.ndarray:
    """Normalise taps and run the fused scan in the given direction.

    ``a_raw`` is in canonical orientation (computed from the reoriented
    feature map), matching ref.gspn_scan_ref_dir.
    """
    a = normalize_taps(a_raw)
    xc = to_canonical(x, direction)
    lamc = to_canonical(lam, direction)
    hc = gspn_fused(
        xc, a, lamc, kchunk=kchunk, c_tile=c_tile, interpret=interpret
    )
    return from_canonical(hc, direction)
