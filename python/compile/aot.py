"""AOT pipeline: lower every L2 entrypoint to HLO *text* artifacts.

This is the ONLY Python that ever runs in the system, and it runs once at
build time (``make artifacts``). It produces, under ``artifacts/``:

  <name>.hlo.txt      one per entrypoint (HLO text — the interchange format
                      xla_extension 0.5.1 can parse; serialized protos from
                      jax >= 0.5 carry 64-bit instruction ids it rejects)
  <model>.params.bin  initial parameters, little-endian f32, leaves
                      concatenated in jax tree order
  manifest.json       machine-readable index: per entry the file name,
                      ordered input/output specs (shape + dtype), how many
                      leading inputs are parameters, and which params.bin
                      they come from. The Rust runtime is driven entirely
                      by this manifest.

Entry naming convention: ``<family>_<variant>``, e.g. ``scan_h64w64c8n1``,
``classifier_fwd_b8``, ``classifier_train_b8``. Scan entries exist at
several (N, C, H, W) buckets — these are the shape buckets the L3 dynamic
batcher routes into (HLO executables are shape-specialised).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.gspn import gspn_scan, normalize_taps


# ---------------------------------------------------------------------------
# Lowering helper (see /opt/xla-example/gen_hlo.py and aot_recipe.md)
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """jax Lowered -> HLO text via stablehlo -> XlaComputation."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dt(x) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[str(x.dtype)]


def _spec(x, name: str) -> dict:
    return {"name": name, "shape": [int(s) for s in x.shape], "dtype": _dt(x)}


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


class ArtifactWriter:
    """Collects entries + param blobs, writes files and manifest.json."""

    def __init__(self, out_dir: str):
        self.out = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.entries = []
        self.params_bins = {}

    def add_params_bin(self, name: str, params) -> tuple:
        """Write a params.bin; returns (file, leaves) for manifest reuse."""
        leaves, _ = M.flatten_params(params)
        fname = f"{name}.params.bin"
        with open(os.path.join(self.out, fname), "wb") as f:
            for leaf in leaves:
                f.write(np.asarray(leaf, dtype=np.float32).tobytes())
        self.params_bins[name] = fname
        return fname, leaves

    def add(self, name: str, fn, in_specs: list, in_names: list,
            n_params: int = 0, params_bin: str | None = None,
            meta: dict | None = None):
        """Lower fn at in_specs, write <name>.hlo.txt, record manifest entry."""
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *in_specs)
        out_leaves = jax.tree_util.tree_leaves(out_shapes)
        self.entries.append(
            {
                "name": name,
                "file": fname,
                "inputs": [_spec(s, n) for s, n in zip(in_specs, in_names)],
                "outputs": [_spec(s, f"o{i}") for i, s in enumerate(out_leaves)],
                "n_params": n_params,
                "params_bin": params_bin,
                "meta": meta or {},
            }
        )
        print(f"  [{time.time() - t0:6.1f}s] {name}: "
              f"{len(in_specs)} inputs, {len(out_leaves)} outputs, "
              f"{len(text) / 1e6:.2f} MB hlo")

    def finish(self):
        manifest = {
            "version": 1,
            "generated_unix": int(time.time()),
            "jax_version": jax.__version__,
            "entries": self.entries,
        }
        with open(os.path.join(self.out, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        print(f"wrote manifest with {len(self.entries)} entries -> {self.out}")


# ---------------------------------------------------------------------------
# Entry builders
# ---------------------------------------------------------------------------


def scan_entries(w: ArtifactWriter):
    """Standalone fused-scan ops at the serving shape buckets.

    Input taps are *raw logits*; normalisation happens inside the artifact
    so the Rust side never reimplements the Stability-Context Condition.
    """
    buckets = [
        # (n, c, h, wdim, cw, kchunk)
        (1, 8, 64, 64, 1, 0),
        (2, 8, 64, 64, 1, 0),
        (4, 8, 64, 64, 1, 0),
        (1, 8, 128, 128, 1, 0),
        (1, 8, 64, 64, 8, 0),     # per-channel (GSPN-1 semantics)
        (1, 8, 64, 64, 1, 16),    # GSPN-local, kchunk=16
    ]
    for (n, c, h, wd, cw, kchunk) in buckets:
        def fn(x, a_raw, lam, _k=kchunk):
            return gspn_scan(x, normalize_taps(a_raw), lam, _k, 1, True)

        tag = f"scan_h{h}w{wd}c{c}n{n}" + (f"k{kchunk}" if kchunk else "") + (
            "pc" if cw == c else ""
        )
        w.add(
            tag,
            fn,
            [_sds((n, c, h, wd)), _sds((n, cw, 3, h, wd)), _sds((n, c, h, wd))],
            ["x", "a_raw", "lam"],
            meta={"kind": "scan", "n": n, "c": c, "h": h, "w": wd,
                  "cw": cw, "kchunk": kchunk},
        )


def classifier_entries(w: ArtifactWriter, *, attn: bool = False,
                       readout: str = "gap"):
    cfg = M.GspnConfig(readout=readout)
    rng = np.random.default_rng(42)
    if attn:
        params = M.init_attn_classifier(rng, cfg)
        apply, prefix = M.attn_classifier, "attn_classifier"
        train = M.make_train_step(cfg, model=M.attn_classifier)
        evals = M.make_eval_step(cfg, model=M.attn_classifier)
    else:
        params = M.init_classifier(rng, cfg)
        apply = M.classifier
        prefix = "reg_classifier" if readout == "register" else "classifier"
        train = M.make_train_step(cfg)
        evals = M.make_eval_step(cfg)

    pbin, leaves = w.add_params_bin(prefix, params)
    treedef = jax.tree_util.tree_structure(params)
    k = len(leaves)
    pspecs = [_sds(l.shape) for l in leaves]
    pnames = [f"p{i}" for i in range(k)]
    batch = 8
    img = _sds((batch, cfg.in_ch, 32, 32))
    lbl = _sds((batch,), jnp.int32)
    meta = {"kind": "classifier", "model": prefix, "batch": batch,
            "img": 32, "classes": cfg.num_classes,
            "param_count": int(sum(int(np.prod(l.shape)) for l in leaves))}

    def fwd(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:k])
        return apply(p, args[k], cfg)

    w.add(f"{prefix}_fwd_b{batch}", fwd, pspecs + [img], pnames + ["x"],
          n_params=k, params_bin=pbin, meta=meta)

    def train_fn(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:k])
        v = jax.tree_util.tree_unflatten(treedef, args[k:2 * k])
        np_, nv, loss = train(p, v, args[2 * k], args[2 * k + 1])
        return (
            tuple(jax.tree_util.tree_leaves(np_))
            + tuple(jax.tree_util.tree_leaves(nv))
            + (loss,)
        )

    w.add(
        f"{prefix}_train_b{batch}",
        train_fn,
        pspecs + pspecs + [img, lbl],
        pnames + [f"v{i}" for i in range(k)] + ["x", "y"],
        n_params=k,
        params_bin=pbin,
        meta={**meta, "kind": "train_step", "n_vel": k},
    )

    def eval_fn(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:k])
        return evals(p, args[k], args[k + 1])

    w.add(f"{prefix}_eval_b{batch}", eval_fn, pspecs + [img, lbl],
          pnames + ["x", "y"], n_params=k, params_bin=pbin,
          meta={**meta, "kind": "eval_step"})


def segmenter_entries(w: ArtifactWriter):
    """Dense-prediction artifacts (the §6 extension): fwd + train + eval
    at 32x32, batch 4, 2 classes (the synthetic Voronoi task)."""
    cfg = M.SegConfig()
    rng = np.random.default_rng(11)
    params = M.init_segmenter(rng, cfg)
    pbin, leaves = w.add_params_bin("segmenter", params)
    treedef = jax.tree_util.tree_structure(params)
    k = len(leaves)
    pspecs = [_sds(l.shape) for l in leaves]
    pnames = [f"p{i}" for i in range(k)]
    batch, res = 4, 32
    img = _sds((batch, cfg.in_ch, res, res))
    lbl = _sds((batch, res, res), jnp.int32)
    meta = {"kind": "segmenter", "model": "segmenter", "batch": batch,
            "img": res, "classes": cfg.num_classes,
            "param_count": int(sum(int(np.prod(l.shape)) for l in leaves))}

    def fwd(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:k])
        return M.segmenter(p, args[k], cfg)

    w.add(f"segmenter_fwd_b{batch}", fwd, pspecs + [img], pnames + ["x"],
          n_params=k, params_bin=pbin, meta=meta)

    train = M.make_seg_train_step(cfg)

    def train_fn(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:k])
        v = jax.tree_util.tree_unflatten(treedef, args[k:2 * k])
        np_, nv, loss = train(p, v, args[2 * k], args[2 * k + 1])
        return (
            tuple(jax.tree_util.tree_leaves(np_))
            + tuple(jax.tree_util.tree_leaves(nv))
            + (loss,)
        )

    w.add(
        f"segmenter_train_b{batch}",
        train_fn,
        pspecs + pspecs + [img, lbl],
        pnames + [f"v{i}" for i in range(k)] + ["x", "y"],
        n_params=k, params_bin=pbin,
        meta={**meta, "kind": "seg_train_step", "n_vel": k},
    )

    evals = M.make_seg_eval_step(cfg)

    def eval_fn(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:k])
        return evals(p, args[k], args[k + 1])

    w.add(f"segmenter_eval_b{batch}", eval_fn, pspecs + [img, lbl],
          pnames + ["x", "y"], n_params=k, params_bin=pbin,
          meta={**meta, "kind": "seg_eval_step"})


def denoiser_entries(w: ArtifactWriter):
    cfg = M.DenoiserConfig()
    rng = np.random.default_rng(7)
    params = M.init_denoiser(rng, cfg)
    pbin, leaves = w.add_params_bin("denoiser", params)
    treedef = jax.tree_util.tree_structure(params)
    k = len(leaves)
    pspecs = [_sds(l.shape) for l in leaves]
    pnames = [f"p{i}" for i in range(k)]
    meta = {"kind": "denoiser", "dim": cfg.dim, "depth": cfg.depth,
            "param_count": int(sum(int(np.prod(l.shape)) for l in leaves))}

    # Forward at two resolutions — the Fig-5 resolution sweep buckets.
    for (batch, res) in [(4, 16), (1, 32)]:
        def fwd(*args):
            p = jax.tree_util.tree_unflatten(treedef, args[:k])
            return M.denoiser(p, args[k], args[k + 1], cfg)

        w.add(
            f"denoiser_fwd_r{res}_b{batch}",
            fwd,
            pspecs + [_sds((batch, cfg.in_ch, res, res)), _sds((batch,))],
            pnames + ["x", "t"],
            n_params=k, params_bin=pbin,
            meta={**meta, "res": res, "batch": batch},
        )

    # Train step at 16x16 (epsilon-prediction objective).
    train = M.make_denoise_train_step(cfg)
    batch, res = 4, 16

    def train_fn(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:k])
        np_, loss = train(p, args[k], args[k + 1], args[k + 2])
        return tuple(jax.tree_util.tree_leaves(np_)) + (loss,)

    w.add(
        f"denoiser_train_r{res}_b{batch}",
        train_fn,
        pspecs + [
            _sds((batch, cfg.in_ch, res, res)),
            _sds((batch, cfg.in_ch, res, res)),
            _sds((batch,), jnp.int32),
        ],
        pnames + ["x0", "noise", "t"],
        n_params=k, params_bin=pbin,
        meta={**meta, "kind": "denoise_train_step", "res": res, "batch": batch},
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated families: "
                         "scan,classifier,attn,register,segmenter,denoiser")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    w = ArtifactWriter(args.out)
    print("== AOT lowering (jax", jax.__version__, ") ==")
    if only is None or "scan" in only:
        scan_entries(w)
    if only is None or "classifier" in only:
        classifier_entries(w, attn=False)
    if only is None or "attn" in only:
        classifier_entries(w, attn=True)
    if only is None or "register" in only:
        classifier_entries(w, readout="register")
    if only is None or "segmenter" in only:
        segmenter_entries(w)
    if only is None or "denoiser" in only:
        denoiser_entries(w)
    w.finish()


if __name__ == "__main__":
    main()
