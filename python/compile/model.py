"""L2: the GSPN-2 model family in JAX.

This module defines, as pure functions over explicit parameter pytrees:

  * ``gspn_unit``   — the paper's attention-replacement module: optional
    compressive proxy projection (C -> C_proxy, §4.2), four directional
    line scans through the fused Pallas kernel (L1), learned directional
    merge, output modulation ``u`` (Eq. 2), and expansion back to C.
  * ``gspn_block``  — LPU (depthwise conv) + GSPN unit + FFN, each behind
    an RMSNorm with residual connections (the Table-2 block recipe).
  * ``classifier``  — patch-embed stem, stages of blocks with strided
    downsampling, global pool, linear head (the ImageNet-style backbone).
  * ``denoiser``    — timestep-conditioned denoising network (the
    text-to-image/diffusion-lite analog used for Fig 5 / Table S1).
  * ``train_step``  — cross-entropy + SGD-with-momentum update, lowered as
    one HLO module so the Rust training driver never touches Python.

Everything is shape-polymorphic in batch only at trace time; the AOT
pipeline (aot.py) pins concrete shapes per artifact.

The ``mode`` knob selects the propagation flavour:
  "gspn2"  — channel-shared taps (Cw = 1) + compressive proxy (§4.2)
  "gspn1"  — per-channel taps (Cw = C_proxy), no sharing (GSPN-1 semantics)
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from . import layers as L
from .kernels.gspn import DIRECTIONS, gspn_scan, normalize_taps, to_canonical, from_canonical


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GspnConfig:
    """Architecture hyperparameters for one GSPN backbone."""

    name: str = "test-tiny"
    in_ch: int = 3
    num_classes: int = 10
    dims: tuple = (32, 64)          # channels per stage
    depths: tuple = (1, 1)          # blocks per stage
    patch: int = 4                  # stem patch size / stride
    c_proxy: int = 2                # compressive proxy dim (§4.2)
    kchunk: int = 0                 # 0 = global scan, >0 = GSPN-local
    ffn_ratio: int = 4
    mode: str = "gspn2"             # "gspn2" | "gspn1"
    interpret: bool = True          # pallas interpret mode (CPU image)
    readout: str = "gap"            # "gap" | "register" (§6 extension)
    num_registers: int = 4          # register tokens when readout="register"

    @property
    def per_channel(self) -> bool:
        return self.mode == "gspn1"


@dataclasses.dataclass(frozen=True)
class DenoiserConfig:
    """Denoiser (diffusion-lite) hyperparameters."""

    name: str = "denoiser-tiny"
    in_ch: int = 3
    dim: int = 32
    depth: int = 4
    time_dim: int = 64
    c_proxy: int = 4
    kchunk: int = 0
    ffn_ratio: int = 4
    mode: str = "gspn2"
    interpret: bool = True

    @property
    def per_channel(self) -> bool:
        return self.mode == "gspn1"


@dataclasses.dataclass(frozen=True)
class SegConfig:
    """Dense-prediction (segmentation) head over a GSPN encoder.

    Addresses the paper's §6 note that dense-prediction evaluation is
    under-explored: per-pixel logits come from a pixel-shuffle decoder on
    top of the same GSPN blocks, so the propagation path is exercised by
    a task whose labels *require* global context (the synthetic Voronoi
    task in rust/src/train/data.rs)."""

    name: str = "seg-tiny"
    in_ch: int = 3
    num_classes: int = 2
    dim: int = 32
    depth: int = 2
    patch: int = 4                  # stem stride == decoder upsample factor
    c_proxy: int = 2
    kchunk: int = 0
    ffn_ratio: int = 4
    mode: str = "gspn2"
    interpret: bool = True
    readout: str = "dense"          # unused; parity with GspnConfig

    @property
    def per_channel(self) -> bool:
        return self.mode == "gspn1"


# Paper-scale configs (Table 2). These are used for param/MAC accounting and
# (in the Rust model module) cross-checked against the paper's columns; the
# AOT artifacts use the small `test-*` configs so CPU PJRT stays fast.
GSPN2_TINY = GspnConfig(
    name="gspn2-t", num_classes=1000, dims=(64, 128, 320, 512),
    depths=(2, 2, 9, 3), c_proxy=2,
)
GSPN2_SMALL = GspnConfig(
    name="gspn2-s", num_classes=1000, dims=(80, 160, 400, 640),
    depths=(3, 3, 12, 4), c_proxy=2,
)
GSPN2_BASE = GspnConfig(
    name="gspn2-b", num_classes=1000, dims=(104, 208, 520, 832),
    depths=(3, 4, 14, 5), c_proxy=2,
)


# ---------------------------------------------------------------------------
# GSPN unit (the attention replacement)
# ---------------------------------------------------------------------------


def init_gspn_unit(rng: np.random.Generator, c: int, cfg) -> dict:
    """Parameters of one GSPN unit operating on C channels."""
    cp = cfg.c_proxy
    cw = cp if cfg.per_channel else 1
    p = {
        "down": L.init_conv(rng, c, cp, 1),
        "up": L.init_conv(rng, cp, c, 1),
        # Output modulation u (Eq. 2): per proxy-channel gain applied to h.
        "u": jnp.ones((cp,), dtype=jnp.float32),
        # Learned directional-merge logits (softmax-combined).
        "merge": jnp.zeros((len(DIRECTIONS),), dtype=jnp.float32),
    }
    for d in DIRECTIONS:
        # Taps + lambda are input-dependent (computed from the proxy map by
        # 1x1 convs), mirroring GSPN's data-dependent propagation weights.
        p[f"taps_{d}"] = L.init_conv(rng, cp, 3 * cw, 1)
        p[f"lam_{d}"] = L.init_conv(rng, cp, cp, 1)
    return p


def gspn_unit(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Apply the GSPN unit to x: (N, C, H, W) -> (N, C, H, W)."""
    n, c, hdim, wdim = x.shape
    cp = cfg.c_proxy
    cw = cp if cfg.per_channel else 1

    xp = L.conv1x1(p["down"], x)  # (N, Cp, H, W) — compressive proxy (§4.2)
    merge = jax.nn.softmax(p["merge"])

    out = jnp.zeros_like(xp)
    for di, d in enumerate(DIRECTIONS):
        xc = to_canonical(xp, d)  # (N, Cp, Hc, Wc)
        a_raw = L.conv1x1(p[f"taps_{d}"], xc)  # (N, 3*Cw, Hc, Wc)
        a_raw = a_raw.reshape(n, cw, 3, xc.shape[2], xc.shape[3])
        lam = L.conv1x1(p[f"lam_{d}"], xc)  # (N, Cp, Hc, Wc)
        a = normalize_taps(a_raw)
        h = gspn_scan(xc, a, lam, cfg.kchunk, 1, cfg.interpret)
        out = out + merge[di] * from_canonical(h, d)

    out = out * p["u"][None, :, None, None]  # Eq. 2 output modulation
    return L.conv1x1(p["up"], out)  # expand back to C


# ---------------------------------------------------------------------------
# GSPN block: LPU + GSPN + FFN (pre-norm residual)
# ---------------------------------------------------------------------------


def init_gspn_block(rng: np.random.Generator, c: int, cfg) -> dict:
    hid = c * cfg.ffn_ratio
    return {
        "lpu": L.init_conv(rng, c, c, 3, groups=c, zero=True),
        "norm1": L.init_norm(c),
        "gspn": init_gspn_unit(rng, c, cfg),
        "norm2": L.init_norm(c),
        "ffn1": L.init_conv(rng, c, hid, 1),
        "ffn2": L.init_conv(rng, hid, c, 1),
    }


def gspn_block(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    x = x + L.dwconv3x3(p["lpu"], x)  # Local Perception Unit [52]
    x = x + gspn_unit(p["gspn"], L.rmsnorm(p["norm1"], x), cfg)
    y = L.rmsnorm(p["norm2"], x)
    y = L.conv1x1(p["ffn2"], L.gelu(L.conv1x1(p["ffn1"], y)))
    return x + y


# ---------------------------------------------------------------------------
# Classifier backbone
# ---------------------------------------------------------------------------


def init_classifier(rng: np.random.Generator, cfg: GspnConfig) -> dict:
    p = {"stem": L.init_conv(rng, cfg.in_ch, cfg.dims[0], cfg.patch)}
    for si, (dim, depth) in enumerate(zip(cfg.dims, cfg.depths)):
        if si > 0:
            p[f"down{si}"] = L.init_conv(rng, cfg.dims[si - 1], dim, 2)
        for bi in range(depth):
            p[f"s{si}b{bi}"] = init_gspn_block(rng, dim, cfg)
    p["norm"] = L.init_norm(cfg.dims[-1])
    if cfg.readout == "register":
        p["readout"] = L.init_register_readout(rng, cfg.dims[-1], cfg.num_registers)
    p["head"] = L.init_linear(rng, cfg.dims[-1], cfg.num_classes)
    return p


def classifier(p: dict, x: jnp.ndarray, cfg: GspnConfig) -> jnp.ndarray:
    """x: (N, in_ch, H, W) -> logits (N, num_classes)."""
    x = L.conv2d(p["stem"], x, stride=cfg.patch)
    for si, (dim, depth) in enumerate(zip(cfg.dims, cfg.depths)):
        if si > 0:
            x = L.conv2d(p[f"down{si}"], x, stride=2)
        for bi in range(depth):
            x = gspn_block(p[f"s{si}b{bi}"], x, cfg)
    x = L.rmsnorm(p["norm"], x)
    if cfg.readout == "register":
        return L.linear(p["head"], L.register_readout(p["readout"], x))
    return L.linear(p["head"], L.global_avg_pool(x))


# ---------------------------------------------------------------------------
# Segmenter (dense prediction) — §6 extension
# ---------------------------------------------------------------------------


def init_segmenter(rng: np.random.Generator, cfg: SegConfig) -> dict:
    p = {"stem": L.init_conv(rng, cfg.in_ch, cfg.dim, cfg.patch)}
    for bi in range(cfg.depth):
        p[f"b{bi}"] = init_gspn_block(rng, cfg.dim, cfg)
    p["norm"] = L.init_norm(cfg.dim)
    p["head"] = L.init_conv(rng, cfg.dim, cfg.num_classes * cfg.patch * cfg.patch, 1)
    return p


def segmenter(p: dict, x: jnp.ndarray, cfg: SegConfig) -> jnp.ndarray:
    """x: (N, in_ch, H, W) -> per-pixel logits (N, num_classes, H, W)."""
    x = L.conv2d(p["stem"], x, stride=cfg.patch)
    for bi in range(cfg.depth):
        x = gspn_block(p[f"b{bi}"], x, cfg)
    x = L.rmsnorm(p["norm"], x)
    x = L.conv1x1(p["head"], x)  # (N, classes*patch^2, H/p, W/p)
    return L.depth_to_space(x, cfg.patch)


def pixel_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean per-pixel CE. logits (N, C, H, W), labels (N, H, W) int32."""
    logp = jax.nn.log_softmax(logits, axis=1)
    onehot = jax.nn.one_hot(labels, logits.shape[1], axis=1, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=1))


def make_seg_train_step(cfg: SegConfig, lr: float = 0.05, momentum: float = 0.9):
    """SGD+momentum train step over the segmenter (pixel CE)."""

    def loss_fn(params, x, y):
        return pixel_cross_entropy(segmenter(params, x, cfg), y)

    def train_step(params, vel, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        new_vel = jax.tree_util.tree_map(lambda v, g: momentum * v - lr * g, vel, grads)
        new_params = jax.tree_util.tree_map(lambda p, v: p + v, params, new_vel)
        return new_params, new_vel, loss

    return train_step


def make_seg_eval_step(cfg: SegConfig):
    def eval_step(params, x, y):
        logits = segmenter(params, x, cfg)
        loss = pixel_cross_entropy(logits, y)
        pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
        correct = jnp.sum((pred == y).astype(jnp.int32))
        return loss, correct

    return eval_step


# ---------------------------------------------------------------------------
# Denoiser (diffusion-lite) — the text-to-image analog
# ---------------------------------------------------------------------------


def init_denoiser(rng: np.random.Generator, cfg: DenoiserConfig) -> dict:
    p = {
        "stem": L.init_conv(rng, cfg.in_ch, cfg.dim, 3),
        "t1": L.init_linear(rng, cfg.time_dim, cfg.dim),
        "t2": L.init_linear(rng, cfg.dim, cfg.dim),
        "out_norm": L.init_norm(cfg.dim),
        "out": L.init_conv(rng, cfg.dim, cfg.in_ch, 3, zero=True),
    }
    for bi in range(cfg.depth):
        p[f"b{bi}"] = init_gspn_block(rng, cfg.dim, cfg)
    return p


def denoiser(p: dict, x: jnp.ndarray, t: jnp.ndarray, cfg: DenoiserConfig) -> jnp.ndarray:
    """Predict noise: x (N, C, H, W), t (N,) -> (N, C, H, W)."""
    emb = L.timestep_embedding(t, cfg.time_dim)
    emb = L.linear(p["t2"], L.gelu(L.linear(p["t1"], emb)))  # (N, dim)
    y = L.conv2d(p["stem"], x) + emb[:, :, None, None]
    for bi in range(cfg.depth):
        y = gspn_block(p[f"b{bi}"], y, cfg)
    return L.conv2d(p["out"], L.rmsnorm(p["out_norm"], y))


# ---------------------------------------------------------------------------
# Attention baseline (for Table 2 / Fig 5-style comparisons at small scale)
# ---------------------------------------------------------------------------


def init_attn_unit(rng: np.random.Generator, c: int) -> dict:
    return {
        "qkv": L.init_conv(rng, c, 3 * c, 1),
        "proj": L.init_conv(rng, c, c, 1),
    }


def attn_unit(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Single-head global self-attention over all H*W tokens (quadratic)."""
    n, c, hdim, wdim = x.shape
    qkv = L.conv1x1(p["qkv"], x).reshape(n, 3, c, hdim * wdim)
    q, k, v = qkv[:, 0], qkv[:, 1], qkv[:, 2]  # (N, C, T)
    att = jax.nn.softmax(jnp.einsum("nct,ncs->nts", q, k) / jnp.sqrt(c), axis=-1)
    y = jnp.einsum("nts,ncs->nct", att, v).reshape(n, c, hdim, wdim)
    return L.conv1x1(p["proj"], y)


def init_attn_block(rng: np.random.Generator, c: int, ffn_ratio: int = 4) -> dict:
    hid = c * ffn_ratio
    return {
        "norm1": L.init_norm(c),
        "attn": init_attn_unit(rng, c),
        "norm2": L.init_norm(c),
        "ffn1": L.init_conv(rng, c, hid, 1),
        "ffn2": L.init_conv(rng, hid, c, 1),
    }


def attn_block(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    x = x + attn_unit(p["attn"], L.rmsnorm(p["norm1"], x))
    y = L.rmsnorm(p["norm2"], x)
    return x + L.conv1x1(p["ffn2"], L.gelu(L.conv1x1(p["ffn1"], y)))


def init_attn_classifier(rng: np.random.Generator, cfg: GspnConfig) -> dict:
    """Same macro-architecture as `classifier` but with attention blocks."""
    p = {"stem": L.init_conv(rng, cfg.in_ch, cfg.dims[0], cfg.patch)}
    for si, (dim, depth) in enumerate(zip(cfg.dims, cfg.depths)):
        if si > 0:
            p[f"down{si}"] = L.init_conv(rng, cfg.dims[si - 1], dim, 2)
        for bi in range(depth):
            p[f"s{si}b{bi}"] = init_attn_block(rng, dim)
    p["norm"] = L.init_norm(cfg.dims[-1])
    p["head"] = L.init_linear(rng, cfg.dims[-1], cfg.num_classes)
    return p


def attn_classifier(p: dict, x: jnp.ndarray, cfg: GspnConfig) -> jnp.ndarray:
    x = L.conv2d(p["stem"], x, stride=cfg.patch)
    for si, (dim, depth) in enumerate(zip(cfg.dims, cfg.depths)):
        if si > 0:
            x = L.conv2d(p[f"down{si}"], x, stride=2)
        for bi in range(depth):
            x = attn_block(p[f"s{si}b{bi}"], x)
    x = L.rmsnorm(p["norm"], x)
    return L.linear(p["head"], L.global_avg_pool(x))


# ---------------------------------------------------------------------------
# Training step (classifier): cross-entropy + SGD momentum, one HLO module
# ---------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def make_train_step(cfg: GspnConfig, lr: float = 0.03, momentum: float = 0.9,
                    model=None):
    """Returns train_step(params, velocity, x, y) -> (params', velocity', loss).

    `model` defaults to the GSPN classifier; pass `attn_classifier` for the
    attention baseline so both lower through the identical driver.
    """
    apply = model or classifier

    def loss_fn(params, x, y):
        return cross_entropy(apply(params, x, cfg), y)

    def train_step(params, vel, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        new_vel = jax.tree_util.tree_map(
            lambda v, g: momentum * v + g, vel, grads
        )
        new_params = jax.tree_util.tree_map(
            lambda p_, v: p_ - lr * v, params, new_vel
        )
        return new_params, new_vel, loss

    return train_step


def make_eval_step(cfg: GspnConfig, model=None):
    """Returns eval_step(params, x, y) -> (loss, n_correct)."""
    apply = model or classifier

    def eval_step(params, x, y):
        logits = apply(params, x, cfg)
        loss = cross_entropy(logits, y)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
        return loss, correct

    return eval_step


# ---------------------------------------------------------------------------
# Denoiser training step (epsilon-prediction DDPM-style objective)
# ---------------------------------------------------------------------------


def ddpm_alphas(steps: int = 100) -> np.ndarray:
    """Linear-beta DDPM schedule; returns sqrt_alpha_bar, sqrt_1m_alpha_bar."""
    betas = np.linspace(1e-4, 0.02, steps, dtype=np.float64)
    alpha_bar = np.cumprod(1.0 - betas)
    return (
        np.sqrt(alpha_bar).astype(np.float32),
        np.sqrt(1.0 - alpha_bar).astype(np.float32),
    )


def make_denoise_train_step(cfg: DenoiserConfig, lr: float = 1e-3,
                            steps: int = 100):
    sa, s1 = ddpm_alphas(steps)
    sa_j, s1_j = jnp.asarray(sa), jnp.asarray(s1)

    def loss_fn(params, x0, noise, t):
        xt = sa_j[t][:, None, None, None] * x0 + s1_j[t][:, None, None, None] * noise
        pred = denoiser(params, xt, t.astype(jnp.float32), cfg)
        return jnp.mean(jnp.square(pred - noise))

    def train_step(params, x0, noise, t):
        loss, grads = jax.value_and_grad(loss_fn)(params, x0, noise, t)
        new_params = jax.tree_util.tree_map(lambda p_, g: p_ - lr * g, params, grads)
        return new_params, loss

    return train_step


# ---------------------------------------------------------------------------
# Parameter pytree <-> flat list bridge (shared with aot.py and Rust)
# ---------------------------------------------------------------------------


def flatten_params(params):
    """Deterministic flatten: returns (leaves, treedef)."""
    return jax.tree_util.tree_flatten(params)


def param_count(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
