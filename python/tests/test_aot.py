"""AOT pipeline tests: manifest integrity and HLO-text round-trip.

These check the artifact *contract* the Rust runtime relies on, without
needing the Rust side: files exist, shapes line up, params.bin sizes match
the manifest, and the HLO text parses back through xla_client and executes
with the same numerics as the live JAX function.
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile.kernels.gspn import gspn_scan, normalize_taps

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestManifest:
    def test_entries_present(self):
        m = manifest()
        names = {e["name"] for e in m["entries"]}
        for want in (
            "scan_h64w64c8n1",
            "classifier_fwd_b8",
            "classifier_train_b8",
            "classifier_eval_b8",
            "attn_classifier_train_b8",
            "denoiser_fwd_r16_b4",
            "denoiser_train_r16_b4",
        ):
            assert want in names, f"missing artifact {want}"

    def test_files_exist(self):
        m = manifest()
        for e in m["entries"]:
            assert os.path.exists(os.path.join(ART, e["file"])), e["file"]

    def test_params_bin_sizes(self):
        m = manifest()
        seen = set()
        for e in m["entries"]:
            if not e["params_bin"] or e["params_bin"] in seen:
                continue
            seen.add(e["params_bin"])
            n_param_floats = sum(
                int(np.prod(i["shape"]))
                for i in e["inputs"][: e["n_params"]]
            )
            size = os.path.getsize(os.path.join(ART, e["params_bin"]))
            assert size == 4 * n_param_floats, (e["params_bin"], size)

    def test_train_step_io_symmetry(self):
        """train outputs = params' + vel' + loss matching input specs."""
        m = manifest()
        e = next(x for x in m["entries"] if x["name"] == "classifier_train_b8")
        k = e["n_params"]
        ins, outs = e["inputs"], e["outputs"]
        assert len(outs) == 2 * k + 1
        for i in range(2 * k):
            assert ins[i]["shape"] == outs[i]["shape"], i
        assert outs[-1]["shape"] == []

    def test_dtypes_valid(self):
        m = manifest()
        for e in m["entries"]:
            for s in e["inputs"] + e["outputs"]:
                assert s["dtype"] in ("f32", "i32", "u32")

    def test_scan_buckets_cover_serving_shapes(self):
        m = manifest()
        scans = [e for e in m["entries"] if e["meta"].get("kind") == "scan"]
        ns = sorted(e["meta"]["n"] for e in scans
                    if e["meta"]["h"] == 64 and e["meta"]["cw"] == 1
                    and not e["meta"]["kchunk"])
        assert ns == [1, 2, 4], ns


class TestHloStructure:
    """Structural HLO-text checks. The numeric HLO->PJRT round-trip runs on
    the Rust side (rust/tests/runtime_roundtrip.rs) against xla_extension
    0.5.1 — the version that actually consumes these files."""

    def test_scan_hlo_entry_signature(self):
        m = manifest()
        e = next(x for x in m["entries"] if x["name"] == "scan_h64w64c8n1")
        with open(os.path.join(ART, e["file"])) as f:
            text = f.read()
        assert "HloModule" in text
        entry = [l for l in text.splitlines() if l.startswith("ENTRY")]
        assert len(entry) == 1
        # Entry parameters declared as `%x = f32[dims] parameter(i)`.
        body = text[text.index(entry[0]):]
        for i, spec in enumerate(e["inputs"]):
            dims = ",".join(str(d) for d in spec["shape"])
            assert f"f32[{dims}]{{" in body.replace(" ", "") or (
                f"f32[{dims}]" in body
            ), (spec,)
            assert f"parameter({i})" in body, i

    def test_all_entries_have_single_entry_computation(self):
        m = manifest()
        for e in m["entries"]:
            with open(os.path.join(ART, e["file"])) as f:
                text = f.read()
            assert text.count("\nENTRY") + text.startswith("ENTRY") >= 1, e["name"]
            assert "HloModule" in text, e["name"]

    def test_parameter_count_matches_manifest(self):
        m = manifest()
        for e in m["entries"]:
            with open(os.path.join(ART, e["file"])) as f:
                text = f.read()
            entry_line = next(
                l for l in text.splitlines() if l.startswith("ENTRY")
            )
            body = text[text.index(entry_line):]
            n_params = sum(
                1 for i in range(len(e["inputs"]) + 2)
                if f"parameter({i})" in body
            )
            assert n_params == len(e["inputs"]), (e["name"], n_params)

    def test_hlo_has_while_loop_not_unrolled(self):
        """The fused scan lowers as a loop — the single-kernel design — not
        W unrolled steps (keeps artifact size O(1) in W)."""
        m = manifest()
        e = next(x for x in m["entries"] if x["name"] == "scan_h128w128c8n1")
        with open(os.path.join(ART, e["file"])) as f:
            text = f.read()
        assert "while" in text, "expected a while loop in the lowered scan"
        assert len(text) < 5_000_000


def _hlo_text_to_stablehlo_noop(text):  # pragma: no cover - helper stub
    return text
