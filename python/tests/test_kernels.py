"""L1 kernel correctness: fused Pallas scan vs the numpy oracle.

Covers: shape/dtype sweeps (hypothesis), all four directions, chunked
(GSPN-local) propagation, channel-shared vs per-channel taps, c_tile
(2D-block) variants, the Stability-Context Condition, and the
linear-attention G-matrix identity of Eq. 4.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gspn import (
    DIRECTIONS,
    gspn_fused,
    gspn_scan,
    gspn_scan_dir,
    normalize_taps,
)
from compile.kernels.naive import gspn_naive

RTOL, ATOL = 1e-5, 1e-5


def rand_case(rng, n, c, h, w, cw):
    x = rng.normal(size=(n, c, h, w)).astype(np.float32)
    a_raw = rng.normal(size=(n, cw, 3, h, w)).astype(np.float32)
    lam = rng.normal(size=(n, c, h, w)).astype(np.float32)
    return x, a_raw, lam


# ---------------------------------------------------------------------------
# Tap normalisation (Stability-Context Condition)
# ---------------------------------------------------------------------------


class TestNormalizeTaps:
    def test_rows_sum_to_one(self):
        rng = np.random.default_rng(0)
        a_raw = rng.normal(size=(2, 3, 3, 7, 5)).astype(np.float32)
        a = np.asarray(normalize_taps(jnp.asarray(a_raw)))
        np.testing.assert_allclose(a.sum(axis=2), 1.0, rtol=1e-6)

    def test_boundary_taps_zero(self):
        rng = np.random.default_rng(1)
        a_raw = rng.normal(size=(1, 1, 3, 6, 4)).astype(np.float32)
        a = np.asarray(normalize_taps(jnp.asarray(a_raw)))
        assert np.all(a[:, :, 0, 0, :] == 0.0), "up tap at top row must be 0"
        assert np.all(a[:, :, 2, -1, :] == 0.0), "down tap at bottom row must be 0"

    def test_matches_numpy_oracle(self):
        rng = np.random.default_rng(2)
        a_raw = rng.normal(size=(2, 2, 3, 5, 4)).astype(np.float32)
        got = np.asarray(normalize_taps(jnp.asarray(a_raw)))
        want = ref.normalize_taps(a_raw)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_all_positive(self):
        rng = np.random.default_rng(3)
        a_raw = (rng.normal(size=(1, 1, 3, 4, 4)) * 10).astype(np.float32)
        a = np.asarray(normalize_taps(jnp.asarray(a_raw)))
        assert np.all(a >= 0.0)


# ---------------------------------------------------------------------------
# Fused kernel vs oracle (hypothesis sweep)
# ---------------------------------------------------------------------------


class TestFusedVsOracle:
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 3),
        c=st.integers(1, 6),
        h=st.integers(2, 12),
        w=st.integers(1, 12),
        shared=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n, c, h, w, shared, seed):
        rng = np.random.default_rng(seed)
        cw = 1 if shared else c
        x, a_raw, lam = rand_case(rng, n, c, h, w, cw)
        want = ref.gspn_scan_ref(x, a_raw, lam)
        a = normalize_taps(jnp.asarray(a_raw))
        got = np.asarray(gspn_fused(jnp.asarray(x), a, jnp.asarray(lam)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("c_tile", [1, 2, 4])
    def test_c_tile_invariance(self, c_tile):
        """The 2D-block knob (cSlice analog) must not change numerics."""
        rng = np.random.default_rng(10)
        x, a_raw, lam = rand_case(rng, 2, 4, 8, 8, 1)
        a = normalize_taps(jnp.asarray(a_raw))
        base = np.asarray(gspn_fused(jnp.asarray(x), a, jnp.asarray(lam), c_tile=1))
        got = np.asarray(gspn_fused(jnp.asarray(x), a, jnp.asarray(lam), c_tile=c_tile))
        np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("kchunk", [1, 2, 4, 8])
    def test_chunked_matches_oracle(self, kchunk):
        rng = np.random.default_rng(11)
        x, a_raw, lam = rand_case(rng, 1, 3, 6, 8, 1)
        want = ref.gspn_scan_ref(x, a_raw, lam, kchunk=kchunk)
        a = normalize_taps(jnp.asarray(a_raw))
        got = np.asarray(
            gspn_fused(jnp.asarray(x), a, jnp.asarray(lam), kchunk=kchunk)
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_chunk_locality(self):
        """GSPN-local: perturbing chunk 0 must not affect chunk 1 outputs."""
        rng = np.random.default_rng(12)
        x, a_raw, lam = rand_case(rng, 1, 2, 4, 8, 1)
        a = normalize_taps(jnp.asarray(a_raw))
        out1 = np.asarray(gspn_fused(jnp.asarray(x), a, jnp.asarray(lam), kchunk=4))
        x2 = x.copy()
        x2[..., :4] += 100.0
        out2 = np.asarray(gspn_fused(jnp.asarray(x2), a, jnp.asarray(lam), kchunk=4))
        np.testing.assert_allclose(out1[..., 4:], out2[..., 4:], rtol=1e-6)
        assert np.abs(out1[..., :4] - out2[..., :4]).max() > 1.0

    def test_global_scan_is_cross_chunk(self):
        """Without chunking, early columns must influence late columns."""
        rng = np.random.default_rng(13)
        x, a_raw, lam = rand_case(rng, 1, 1, 4, 8, 1)
        a = normalize_taps(jnp.asarray(a_raw))
        out1 = np.asarray(gspn_fused(jnp.asarray(x), a, jnp.asarray(lam)))
        x2 = x.copy()
        x2[..., 0] += 100.0
        out2 = np.asarray(gspn_fused(jnp.asarray(x2), a, jnp.asarray(lam)))
        assert np.abs(out1[..., -1] - out2[..., -1]).max() > 1e-3

    def test_bf16_runs(self):
        """bf16 inputs (TPU-MXU readiness): accumulate f32, cast back."""
        rng = np.random.default_rng(14)
        x, a_raw, lam = rand_case(rng, 1, 2, 4, 6, 1)
        a = normalize_taps(jnp.asarray(a_raw, dtype=jnp.bfloat16))
        got = gspn_fused(
            jnp.asarray(x, dtype=jnp.bfloat16),
            a,
            jnp.asarray(lam, dtype=jnp.bfloat16),
        )
        assert got.dtype == jnp.bfloat16
        want = ref.gspn_scan_ref(x, a_raw, lam)
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32), want, rtol=0.15, atol=0.15
        )


# ---------------------------------------------------------------------------
# Naive (GSPN-1 structure) cross-check
# ---------------------------------------------------------------------------


class TestNaiveBaseline:
    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(1, 2),
        c=st.integers(1, 4),
        h=st.integers(2, 8),
        w=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_oracle(self, n, c, h, w, seed):
        rng = np.random.default_rng(seed)
        x, a_raw, lam = rand_case(rng, n, c, h, w, c)
        want = ref.gspn_scan_ref(x, a_raw, lam)
        a = normalize_taps(jnp.asarray(a_raw))
        got = np.asarray(gspn_naive(jnp.asarray(x), a, jnp.asarray(lam)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_matches_fused_exactly_structured_inputs(self):
        """Fused and naive must agree on identical normalised taps."""
        rng = np.random.default_rng(20)
        x, a_raw, lam = rand_case(rng, 2, 3, 7, 9, 1)
        a = normalize_taps(jnp.asarray(a_raw))
        f = np.asarray(gspn_fused(jnp.asarray(x), a, jnp.asarray(lam)))
        nv = np.asarray(gspn_naive(jnp.asarray(x), a, jnp.asarray(lam)))
        np.testing.assert_allclose(f, nv, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Directions
# ---------------------------------------------------------------------------


class TestDirections:
    @pytest.mark.parametrize("direction", DIRECTIONS)
    def test_direction_matches_oracle(self, direction):
        rng = np.random.default_rng(30)
        x = rng.normal(size=(1, 2, 6, 8)).astype(np.float32)
        lam = rng.normal(size=(1, 2, 6, 8)).astype(np.float32)
        hc = 8 if direction in ("t2b", "b2t") else 6
        wc = 6 if direction in ("t2b", "b2t") else 8
        a_raw = rng.normal(size=(1, 1, 3, hc, wc)).astype(np.float32)
        want = ref.gspn_scan_ref_dir(x, a_raw, lam, direction=direction)
        got = np.asarray(
            gspn_scan_dir(
                jnp.asarray(x), jnp.asarray(a_raw), jnp.asarray(lam),
                direction=direction,
            )
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("direction", DIRECTIONS)
    def test_canonical_roundtrip(self, direction):
        rng = np.random.default_rng(31)
        t = jnp.asarray(rng.normal(size=(2, 3, 5, 7)).astype(np.float32))
        from compile.kernels.gspn import to_canonical, from_canonical

        rt = from_canonical(to_canonical(t, direction), direction)
        np.testing.assert_array_equal(np.asarray(rt), np.asarray(t))

    def test_r2l_is_flipped_l2r(self):
        rng = np.random.default_rng(32)
        x = rng.normal(size=(1, 1, 4, 6)).astype(np.float32)
        lam = rng.normal(size=(1, 1, 4, 6)).astype(np.float32)
        a_raw = rng.normal(size=(1, 1, 3, 4, 6)).astype(np.float32)
        l2r = ref.gspn_scan_ref_dir(x, a_raw, lam, direction="l2r")
        r2l = ref.gspn_scan_ref_dir(
            x[..., ::-1].copy(), a_raw, lam[..., ::-1].copy(), direction="r2l"
        )
        np.testing.assert_allclose(l2r, r2l[..., ::-1], rtol=1e-6)


# ---------------------------------------------------------------------------
# Stability-Context Condition consequences + Eq. 4 identity
# ---------------------------------------------------------------------------


class TestStability:
    def test_hidden_state_bounded(self):
        """Row-stochastic w => ||h_i||_inf <= sum_j ||lam_j * x_j||_inf."""
        rng = np.random.default_rng(40)
        x, a_raw, lam = rand_case(rng, 1, 1, 8, 32, 1)
        a = normalize_taps(jnp.asarray(a_raw))
        h = np.asarray(gspn_fused(jnp.asarray(x), a, jnp.asarray(lam)))
        bound = np.cumsum(np.abs(lam * x).max(axis=2), axis=-1)  # (1,1,W)
        assert np.all(np.abs(h).max(axis=2) <= bound + 1e-5)

    def test_constant_preserved(self):
        """With lam*x = 0 after column 0 and h_0 = const, the row-stochastic
        propagation keeps h constant (mass conservation per row)."""
        h, w = 6, 10
        x = np.zeros((1, 1, h, w), dtype=np.float32)
        x[..., 0] = 1.0
        lam = np.ones_like(x)
        rng = np.random.default_rng(41)
        a_raw = rng.normal(size=(1, 1, 3, h, w)).astype(np.float32)
        a = normalize_taps(jnp.asarray(a_raw))
        out = np.asarray(gspn_fused(jnp.asarray(x), a, jnp.asarray(lam)))
        np.testing.assert_allclose(out[..., -1], 1.0, rtol=1e-5)

    def test_linearity_in_x(self):
        rng = np.random.default_rng(42)
        x1, a_raw, lam = rand_case(rng, 1, 2, 5, 7, 1)
        x2 = rng.normal(size=x1.shape).astype(np.float32)
        a = normalize_taps(jnp.asarray(a_raw))

        def run(x):
            return np.asarray(gspn_fused(jnp.asarray(x), a, jnp.asarray(lam)))

        np.testing.assert_allclose(
            run(2.5 * x1 + 0.5 * x2), 2.5 * run(x1) + 0.5 * run(x2),
            rtol=1e-4, atol=1e-4,
        )

    def test_eq4_g_matrix_identity(self):
        """vec(h) == G vec(x) with G the block lower-triangular of Eq. 4."""
        rng = np.random.default_rng(43)
        n, c, h, w = 1, 2, 4, 5
        x, a_raw, lam = rand_case(rng, n, c, h, w, 1)
        want = ref.gspn_scan_ref(x, a_raw, lam)
        for ci in range(c):
            g = ref.gspn_expand_g(a_raw, lam, 0, ci)
            xv = x[0, ci].T.reshape(-1)  # stack columns
            hv = g @ xv
            np.testing.assert_allclose(
                hv.reshape(w, h).T, want[0, ci], rtol=1e-6, atol=1e-8
            )

    def test_g_row_sums_bounded(self):
        """Each row of G sums to <= max-lam * W (no amplification blowup)."""
        rng = np.random.default_rng(44)
        x, a_raw, lam = rand_case(rng, 1, 1, 4, 6, 1)
        lam_abs = np.abs(lam)
        g = ref.gspn_expand_g(a_raw, lam_abs, 0, 0)
        assert g.min() >= 0.0
        assert g.sum(axis=1).max() <= lam_abs.max() * 6 + 1e-6


# ---------------------------------------------------------------------------
# Autodiff (custom VJP with the fused backward kernel)
# ---------------------------------------------------------------------------


class TestAutodiff:
    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(1, 2),
        c=st.integers(1, 3),
        h=st.integers(2, 6),
        w=st.integers(1, 6),
        shared=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_vjp_matches_naive_autodiff(self, n, c, h, w, shared, seed):
        rng = np.random.default_rng(seed)
        cw = 1 if shared else c
        x, a_raw, lam = rand_case(rng, n, c, h, w, cw)
        g = rng.normal(size=x.shape).astype(np.float32)
        xj, aj, lj = jnp.asarray(x), jnp.asarray(a_raw), jnp.asarray(lam)

        def loss_fused(x, a_raw, lam):
            return jnp.sum(gspn_scan(x, normalize_taps(a_raw), lam, 0, 1, True) * g)

        def loss_naive(x, a_raw, lam):
            return jnp.sum(gspn_naive(x, normalize_taps(a_raw), lam) * g)

        gf = jax.grad(loss_fused, argnums=(0, 1, 2))(xj, aj, lj)
        gn = jax.grad(loss_naive, argnums=(0, 1, 2))(xj, aj, lj)
        for got, want in zip(gf, gn):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4
            )

    @pytest.mark.parametrize("kchunk", [2, 4])
    def test_vjp_chunked(self, kchunk):
        rng = np.random.default_rng(50)
        x, a_raw, lam = rand_case(rng, 1, 2, 4, 8, 1)
        g = rng.normal(size=x.shape).astype(np.float32)
        xj, aj, lj = jnp.asarray(x), jnp.asarray(a_raw), jnp.asarray(lam)

        def lf(x, a, lam):
            return jnp.sum(gspn_scan(x, normalize_taps(a), lam, kchunk, 1, True) * g)

        def ln(x, a, lam):
            return jnp.sum(gspn_naive(x, normalize_taps(a), lam, kchunk=kchunk) * g)

        gf = jax.grad(lf, argnums=(0, 1, 2))(xj, aj, lj)
        gn = jax.grad(ln, argnums=(0, 1, 2))(xj, aj, lj)
        for got, want in zip(gf, gn):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4
            )

    def test_grad_finite_difference(self):
        """dL/dx via VJP vs central differences on a few coordinates."""
        rng = np.random.default_rng(51)
        x, a_raw, lam = rand_case(rng, 1, 1, 3, 4, 1)
        a = normalize_taps(jnp.asarray(a_raw))

        def loss(x):
            return jnp.sum(jnp.square(gspn_scan(jnp.asarray(x), a, jnp.asarray(lam), 0, 1, True)))

        gx = np.asarray(jax.grad(lambda x: loss(x))(jnp.asarray(x)))
        eps = 1e-3
        for (r, i) in [(0, 0), (1, 2), (2, 3)]:
            xp, xm = x.copy(), x.copy()
            xp[0, 0, r, i] += eps
            xm[0, 0, r, i] -= eps
            fd = (float(loss(xp)) - float(loss(xm))) / (2 * eps)
            np.testing.assert_allclose(gx[0, 0, r, i], fd, rtol=2e-2, atol=1e-3)
