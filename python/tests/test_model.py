"""L2 model tests: shapes, invariances, training behaviour."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import layers as L


RNG = np.random.default_rng(0)


@pytest.fixture(scope="module")
def tiny_cfg():
    return M.GspnConfig()


@pytest.fixture(scope="module")
def tiny_params(tiny_cfg):
    return M.init_classifier(np.random.default_rng(0), tiny_cfg)


class TestLayers:
    def test_conv1x1_shape(self):
        p = L.init_conv(np.random.default_rng(0), 4, 7, 1)
        x = jnp.ones((2, 4, 5, 6))
        assert L.conv1x1(p, x).shape == (2, 7, 5, 6)

    def test_conv_stride(self):
        p = L.init_conv(np.random.default_rng(0), 3, 8, 4)
        x = jnp.ones((1, 3, 32, 32))
        assert L.conv2d(p, x, stride=4).shape == (1, 8, 8, 8)

    def test_dwconv_is_depthwise(self):
        """Depthwise conv: channel i output depends only on channel i input."""
        p = L.init_conv(np.random.default_rng(0), 4, 4, 3, groups=4)
        x = np.zeros((1, 4, 6, 6), dtype=np.float32)
        x[0, 2] = 1.0
        y = np.asarray(L.dwconv3x3(p, jnp.asarray(x)))
        yb = np.asarray(L.dwconv3x3(p, jnp.zeros((1, 4, 6, 6))))
        diff = np.abs(y - yb).sum(axis=(0, 2, 3))
        assert diff[2] > 0
        assert np.allclose(diff[[0, 1, 3]], 0)

    def test_rmsnorm_unit_rms(self):
        p = L.init_norm(8)
        x = jnp.asarray(RNG.normal(size=(2, 8, 3, 3)).astype(np.float32) * 10)
        y = np.asarray(L.rmsnorm(p, x))
        rms = np.sqrt((y**2).mean(axis=1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_timestep_embedding_distinct(self):
        e = np.asarray(L.timestep_embedding(jnp.asarray([0.0, 5.0, 50.0]), 16))
        assert e.shape == (3, 16)
        assert np.abs(e[0] - e[1]).max() > 0.1
        assert np.abs(e[1] - e[2]).max() > 0.1


class TestGspnUnit:
    def test_shape_preserved(self, tiny_cfg):
        p = M.init_gspn_unit(np.random.default_rng(1), 16, tiny_cfg)
        x = jnp.asarray(RNG.normal(size=(2, 16, 8, 8)).astype(np.float32))
        y = M.gspn_unit(p, x, tiny_cfg)
        assert y.shape == x.shape

    def test_global_receptive_field(self, tiny_cfg):
        """4-direction propagation: a corner perturbation reaches the
        opposite corner (dense pairwise connectivity claim of §3.2)."""
        p = M.init_gspn_unit(np.random.default_rng(2), 8, tiny_cfg)
        x = RNG.normal(size=(1, 8, 8, 8)).astype(np.float32)
        x2 = x.copy()
        x2[0, :, 0, 0] += 10.0
        y1 = np.asarray(M.gspn_unit(p, jnp.asarray(x), tiny_cfg))
        y2 = np.asarray(M.gspn_unit(p, jnp.asarray(x2), tiny_cfg))
        assert np.abs(y1[0, :, -1, -1] - y2[0, :, -1, -1]).max() > 1e-6

    def test_local_variant_limits_receptive_field(self):
        """kchunk confines propagation: with ONLY the l2r direction active a
        perturbation in a later chunk never reaches an earlier chunk."""
        cfg = M.GspnConfig(kchunk=4)
        p = M.init_gspn_unit(np.random.default_rng(3), 8, cfg)
        x = RNG.normal(size=(1, 8, 8, 8)).astype(np.float32)
        x2 = x.copy()
        x2[0, :, :, 7] += 10.0  # last column, chunk 1
        y1 = np.asarray(M.gspn_unit(p, jnp.asarray(x), cfg))
        y2 = np.asarray(M.gspn_unit(p, jnp.asarray(x2), cfg))
        # r2l direction still crosses chunks in reverse... all four
        # directions use chunked scans, so columns 0..3 only see the
        # perturbation via the r2l scan's chunk [4..7] -> none. The t2b/b2t
        # scans are over transposed axes where chunking splits H; the
        # perturbed column 7 stays in its own W position. Columns 0..3:
        # t2b/b2t scans propagate within a column only, so they cannot
        # carry column-7 information sideways.
        np.testing.assert_allclose(y1[..., :4], y2[..., :4], rtol=1e-5, atol=1e-5)

    def test_gspn1_mode_more_tap_params(self, tiny_cfg):
        cfg1 = M.GspnConfig(mode="gspn1")
        p2 = M.init_gspn_unit(np.random.default_rng(4), 16, tiny_cfg)
        p1 = M.init_gspn_unit(np.random.default_rng(4), 16, cfg1)
        # per-channel taps => 3*C_proxy output channels vs 3.
        assert p1["taps_l2r"]["w"].shape[0] == 3 * cfg1.c_proxy
        assert p2["taps_l2r"]["w"].shape[0] == 3

    def test_proxy_dim_respected(self):
        cfg = M.GspnConfig(c_proxy=4)
        p = M.init_gspn_unit(np.random.default_rng(5), 16, cfg)
        assert p["down"]["w"].shape == (4, 16, 1, 1)
        assert p["up"]["w"].shape == (16, 4, 1, 1)


class TestClassifier:
    def test_logits_shape(self, tiny_cfg, tiny_params):
        x = jnp.asarray(RNG.normal(size=(4, 3, 32, 32)).astype(np.float32))
        logits = M.classifier(tiny_params, x, tiny_cfg)
        assert logits.shape == (4, tiny_cfg.num_classes)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_batch_independence(self, tiny_cfg, tiny_params):
        """Per-sample logits must not depend on batch composition."""
        x = RNG.normal(size=(4, 3, 32, 32)).astype(np.float32)
        full = np.asarray(M.classifier(tiny_params, jnp.asarray(x), tiny_cfg))
        solo = np.asarray(M.classifier(tiny_params, jnp.asarray(x[:1]), tiny_cfg))
        np.testing.assert_allclose(full[:1], solo, rtol=1e-4, atol=1e-5)

    def test_param_count_matches_flatten(self, tiny_cfg, tiny_params):
        leaves, _ = M.flatten_params(tiny_params)
        assert M.param_count(tiny_params) == sum(
            int(np.prod(l.shape)) for l in leaves
        )

    def test_loss_decreases_under_training(self, tiny_cfg, tiny_params):
        rng = np.random.default_rng(9)
        x = jnp.asarray(rng.normal(size=(8, 3, 32, 32)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 10, size=(8,)).astype(np.int32))
        ts = jax.jit(M.make_train_step(tiny_cfg))
        p = tiny_params
        v = jax.tree_util.tree_map(jnp.zeros_like, p)
        losses = []
        for _ in range(6):
            p, v, loss = ts(p, v, x, y)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.8, losses

    def test_eval_step_counts_correct(self, tiny_cfg, tiny_params):
        rng = np.random.default_rng(10)
        x = jnp.asarray(rng.normal(size=(8, 3, 32, 32)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 10, size=(8,)).astype(np.int32))
        es = M.make_eval_step(tiny_cfg)
        loss, correct = es(tiny_params, x, y)
        assert 0 <= int(correct) <= 8
        logits = M.classifier(tiny_params, x, tiny_cfg)
        want = int(np.sum(np.argmax(np.asarray(logits), axis=-1) == np.asarray(y)))
        assert int(correct) == want


class TestDenoiser:
    def test_output_shape(self):
        cfg = M.DenoiserConfig(depth=2)
        p = M.init_denoiser(np.random.default_rng(0), cfg)
        x = jnp.asarray(RNG.normal(size=(2, 3, 16, 16)).astype(np.float32))
        t = jnp.asarray([0.0, 10.0])
        assert M.denoiser(p, x, t, cfg).shape == x.shape

    def test_zero_init_output_head(self):
        """Output conv is zero-init => prediction starts at exactly 0."""
        cfg = M.DenoiserConfig(depth=1)
        p = M.init_denoiser(np.random.default_rng(0), cfg)
        x = jnp.asarray(RNG.normal(size=(1, 3, 8, 8)).astype(np.float32))
        out = np.asarray(M.denoiser(p, x, jnp.asarray([3.0]), cfg))
        np.testing.assert_allclose(out, 0.0, atol=1e-6)

    def test_resolution_polymorphic(self):
        """Same weights run at multiple resolutions (the paper's
        cross-resolution adaptability claim, §C)."""
        cfg = M.DenoiserConfig(depth=1)
        p = M.init_denoiser(np.random.default_rng(1), cfg)
        for res in (8, 16, 24):
            x = jnp.asarray(RNG.normal(size=(1, 3, res, res)).astype(np.float32))
            assert M.denoiser(p, x, jnp.asarray([1.0]), cfg).shape == x.shape

    def test_train_step_reduces_loss(self):
        cfg = M.DenoiserConfig(depth=2, dim=16)
        p = M.init_denoiser(np.random.default_rng(2), cfg)
        ts = jax.jit(M.make_denoise_train_step(cfg, lr=1e-2))
        rng = np.random.default_rng(3)
        x0 = jnp.asarray(rng.normal(size=(4, 3, 8, 8)).astype(np.float32))
        noise = jnp.asarray(rng.normal(size=(4, 3, 8, 8)).astype(np.float32))
        t = jnp.asarray(rng.integers(0, 100, size=(4,)).astype(np.int32))
        losses = []
        for _ in range(8):
            p, loss = ts(p, x0, noise, t)
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_ddpm_schedule_monotone(self):
        sa, s1 = M.ddpm_alphas(100)
        assert np.all(np.diff(sa) < 0)
        assert np.all(np.diff(s1) > 0)
        np.testing.assert_allclose(sa**2 + s1**2, 1.0, rtol=1e-6)


class TestAttentionBaseline:
    def test_logits_shape(self, tiny_cfg):
        p = M.init_attn_classifier(np.random.default_rng(0), tiny_cfg)
        x = jnp.asarray(RNG.normal(size=(2, 3, 32, 32)).astype(np.float32))
        assert M.attn_classifier(p, x, tiny_cfg).shape == (2, 10)

    def test_attention_rows_sum_to_one(self):
        p = M.init_attn_unit(np.random.default_rng(1), 8)
        x = jnp.asarray(RNG.normal(size=(1, 8, 4, 4)).astype(np.float32))
        # attn output for constant v should be that constant.
        y = M.attn_unit(p, x)
        assert y.shape == x.shape

    def test_trains(self, tiny_cfg):
        p = M.init_attn_classifier(np.random.default_rng(2), tiny_cfg)
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(8, 3, 32, 32)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 10, size=(8,)).astype(np.int32))
        ts = jax.jit(M.make_train_step(tiny_cfg, model=M.attn_classifier))
        v = jax.tree_util.tree_map(jnp.zeros_like, p)
        l0 = None
        for i in range(5):
            p, v, loss = ts(p, v, x, y)
            l0 = l0 if l0 is not None else float(loss)
        assert float(loss) < l0


class TestCrossEntropy:
    def test_perfect_prediction_low_loss(self):
        logits = jnp.asarray([[10.0, -10.0], [-10.0, 10.0]])
        y = jnp.asarray([0, 1])
        assert float(M.cross_entropy(logits, y)) < 1e-4

    def test_uniform_is_log_k(self):
        logits = jnp.zeros((4, 10))
        y = jnp.asarray([0, 1, 2, 3])
        np.testing.assert_allclose(
            float(M.cross_entropy(logits, y)), np.log(10.0), rtol=1e-5
        )


class TestRegisterReadout:
    """§6-limitation extension: CLS/register-token summary head."""

    def test_readout_shape(self):
        rng = np.random.default_rng(3)
        p = L.init_register_readout(rng, 16, k=4)
        x = jnp.asarray(rng.normal(size=(2, 16, 5, 7)), jnp.float32)
        out = L.register_readout(p, x)
        assert out.shape == (2, 16)

    def test_attention_rows_are_stochastic(self):
        # The (K, HW) attention matrix rows must sum to one.
        rng = np.random.default_rng(4)
        c, k = 8, 3
        p = L.init_register_readout(rng, c, k=k)
        x = jnp.asarray(rng.normal(size=(1, c, 4, 4)), jnp.float32)
        toks = x.reshape(1, c, 16).transpose(0, 2, 1)
        keys = L.linear(p["wk"], toks)
        att = jnp.einsum("kc,nlc->nkl", p["reg"], keys) / jnp.sqrt(jnp.float32(c))
        att = jax.nn.softmax(att, axis=-1)
        np.testing.assert_allclose(np.asarray(att.sum(-1)), 1.0, atol=1e-5)

    def test_register_readout_differs_from_gap(self):
        cfg_gap = M.GspnConfig()
        cfg_reg = M.GspnConfig(readout="register")
        rng = np.random.default_rng(0)
        p = M.init_classifier(rng, cfg_reg)
        assert "readout" in p
        x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 3, 32, 32)),
                        jnp.float32)
        logits_reg = M.classifier(p, x, cfg_reg)
        # Same params minus the readout head, read out with GAP.
        p_gap = {k: v for k, v in p.items() if k != "readout"}
        logits_gap = M.classifier(p_gap, x, cfg_gap)
        assert logits_reg.shape == logits_gap.shape
        assert float(jnp.max(jnp.abs(logits_reg - logits_gap))) > 1e-4

    def test_gradients_reach_registers(self):
        cfg = M.GspnConfig(readout="register")
        p = M.init_classifier(np.random.default_rng(2), cfg)
        x = jnp.asarray(np.random.default_rng(5).normal(size=(2, 3, 32, 32)),
                        jnp.float32)
        y = jnp.asarray([1, 3], jnp.int32)

        def loss(params):
            return M.cross_entropy(M.classifier(params, x, cfg), y)

        g = jax.grad(loss)(p)
        gnorm = float(jnp.sum(jnp.abs(g["readout"]["reg"])))
        assert gnorm > 0.0, "no gradient reached the register tokens"

    def test_register_train_step_decreases_loss(self):
        cfg = M.GspnConfig(readout="register")
        rng = np.random.default_rng(6)
        p = M.init_classifier(rng, cfg)
        train = M.make_train_step(cfg)
        vel = jax.tree_util.tree_map(jnp.zeros_like, p)
        x = jnp.asarray(rng.normal(size=(8, 3, 32, 32)), jnp.float32)
        y = jnp.asarray(rng.integers(0, cfg.num_classes, size=8), jnp.int32)
        _, _, loss0 = train(p, vel, x, y)
        for _ in range(8):
            p, vel, loss = train(p, vel, x, y)
        assert float(loss) < float(loss0), f"{float(loss)} !< {float(loss0)}"

    def test_param_count_overhead_is_small(self):
        base = M.param_count(M.init_classifier(np.random.default_rng(0),
                                               M.GspnConfig()))
        reg = M.param_count(M.init_classifier(np.random.default_rng(0),
                                              M.GspnConfig(readout="register")))
        c = M.GspnConfig().dims[-1]
        # 3 projections (c^2 + c each) + k registers.
        expected = 3 * (c * c + c) + 4 * c
        assert reg - base == expected


class TestSegmenter:
    """§6 dense-prediction extension: per-pixel logits via pixel shuffle."""

    def test_depth_to_space_inverts_blocks(self):
        # A (1, 4, 1, 1) tensor with r=2 becomes the 2x2 block laid out
        # row-major.
        x = jnp.arange(4.0).reshape(1, 4, 1, 1)
        y = L.depth_to_space(x, 2)
        assert y.shape == (1, 1, 2, 2)
        np.testing.assert_allclose(
            np.asarray(y)[0, 0], [[0.0, 1.0], [2.0, 3.0]])

    def test_logits_shape_matches_input_resolution(self):
        cfg = M.SegConfig()
        p = M.init_segmenter(np.random.default_rng(0), cfg)
        x = jnp.ones((2, 3, 32, 32))
        out = M.segmenter(p, x, cfg)
        assert out.shape == (2, cfg.num_classes, 32, 32)

    def test_pixel_ce_uniform_is_log_classes(self):
        logits = jnp.zeros((1, 4, 8, 8))
        labels = jnp.zeros((1, 8, 8), jnp.int32)
        loss = M.pixel_cross_entropy(logits, labels)
        np.testing.assert_allclose(float(loss), np.log(4.0), rtol=1e-5)

    def test_pixel_ce_perfect_prediction_is_small(self):
        labels = jnp.asarray(
            np.random.default_rng(0).integers(0, 2, size=(1, 8, 8)), jnp.int32)
        logits = 20.0 * jax.nn.one_hot(labels, 2, axis=1, dtype=jnp.float32)
        assert float(M.pixel_cross_entropy(logits, labels)) < 1e-3

    def test_train_step_decreases_loss(self):
        cfg = M.SegConfig(dim=16, depth=1)
        rng = np.random.default_rng(1)
        p = M.init_segmenter(rng, cfg)
        train = M.make_seg_train_step(cfg)
        vel = jax.tree_util.tree_map(jnp.zeros_like, p)
        x = jnp.asarray(rng.normal(size=(4, 3, 32, 32)), jnp.float32)
        # Global-context labels: left/right half split.
        y = jnp.broadcast_to(
            (jnp.arange(32) >= 16).astype(jnp.int32)[None, None, :], (4, 32, 32))
        _, _, loss0 = train(p, vel, x, y)
        for _ in range(10):
            p, vel, loss = train(p, vel, x, y)
        assert float(loss) < float(loss0), f"{float(loss)} !< {float(loss0)}"

    def test_prediction_uses_global_context(self):
        # Perturbing a far-away input pixel must move a local logit:
        # the GSPN encoder propagates globally even with patch stride 4.
        cfg = M.SegConfig(dim=16, depth=1)
        p = M.init_segmenter(np.random.default_rng(2), cfg)
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(1, 3, 32, 32)), jnp.float32)
        base = M.segmenter(p, x, cfg)
        x2 = x.at[0, :, 0, 0].add(100.0)
        pert = M.segmenter(p, x2, cfg)
        # Row-stochastic propagation diffuses (decays) with distance, so
        # the far-corner effect is small but must be strictly non-zero —
        # a local (conv-only) model of the same geometry gives exactly 0.
        delta = float(jnp.max(jnp.abs((base - pert)[0, :, 28:, 28:])))
        assert delta > 1e-6, f"no corner-to-corner influence ({delta})"
