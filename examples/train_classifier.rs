//! End-to-end training driver (the E2E validation deliverable).
//!
//! Trains the GSPN-2 classifier on the synthetic directional-context
//! task for a few hundred steps entirely from Rust — the train step
//! (forward, backward through the fused Pallas scan via its custom-VJP
//! backward kernel, SGD-momentum update) is a single AOT-compiled HLO
//! module. Logs the loss curve, periodically evaluates accuracy, then
//! trains the attention baseline for the Table-2-style comparison, and
//! writes both curves + a summary to bench_out/.
//!
//! Run: `make artifacts && cargo run --release --example train_classifier -- \
//!        [--steps 300] [--seed 42]`
//!
//! Random-guess accuracy on the 8-octant task is 12.5%; both models
//! should be far above that within a few hundred steps.

use gspn2::runtime::{artifacts_available, Engine};
use gspn2::train::train_classifier;
use gspn2::util::cli::Args;

fn main() -> anyhow::Result<()> {
    if !artifacts_available("artifacts") {
        eprintln!("artifacts/ not built — run `make artifacts` first");
        return Ok(());
    }
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 300);
    let seed = args.u64_or("seed", 42);
    let out = args.str_or("out-dir", "bench_out");
    std::fs::create_dir_all(&out)?;

    let engine = Engine::cpu("artifacts")?;
    let mut summary = String::new();

    for model in ["classifier", "attn_classifier"] {
        println!("\n==== training {model} for {steps} steps ====");
        let report = train_classifier(
            &engine,
            model,
            steps,
            (steps / 25).max(1),
            (steps / 6).max(10),
            seed,
        )?;
        let csv = format!("{out}/loss_curve_{model}.csv");
        std::fs::write(&csv, report.to_csv())?;
        let first = report.curve.first().map(|l| l.loss).unwrap_or(0.0);
        let line = format!(
            "{model}: loss {first:.3} -> {:.3} over {steps} steps, eval acc {:.1}% \
             (chance 12.5%), wall {:.1}s, driver overhead {:.1}%",
            report.final_train_loss,
            report.final_eval_acc * 100.0,
            report.wall_s,
            report.step_overhead_frac * 100.0
        );
        println!("{line}");
        summary.push_str(&line);
        summary.push('\n');

        // ASCII loss curve.
        println!("loss curve ({} logged points):", report.curve.len());
        plot(&report.curve.iter().map(|l| l.loss).collect::<Vec<_>>());
    }

    std::fs::write(format!("{out}/train_e2e_summary.txt"), &summary)?;
    println!("\nsummary written to {out}/train_e2e_summary.txt");
    Ok(())
}

fn plot(losses: &[f64]) {
    if losses.is_empty() {
        return;
    }
    let maxv = losses.iter().cloned().fold(f64::MIN, f64::max);
    let minv = losses.iter().cloned().fold(f64::MAX, f64::min);
    let rows = 10;
    let cols = losses.len().min(72);
    let stride = (losses.len() as f64 / cols as f64).max(1.0);
    for r in 0..rows {
        let hi = maxv - (maxv - minv) * r as f64 / rows as f64;
        let lo = maxv - (maxv - minv) * (r + 1) as f64 / rows as f64;
        let mut line = String::new();
        for cidx in 0..cols {
            let v = losses[((cidx as f64 * stride) as usize).min(losses.len() - 1)];
            line.push(if v <= hi && v > lo { '*' } else { ' ' });
        }
        println!("  {hi:7.3} |{line}");
    }
    println!("          +{}", "-".repeat(cols));
}
