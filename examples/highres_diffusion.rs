//! High-resolution diffusion example (the paper's §5.3 scenario, scaled
//! to this testbed):
//!
//! 1. Briefly trains the GSPN-2 denoiser on structured images (DDPM
//!    epsilon objective) through the AOT train-step artifact.
//! 2. Runs the full DDPM reverse-process sampling loop from Rust using
//!    the denoiser forward artifact — generating actual images.
//! 3. Sweeps generation resolution on the A100 simulator to reproduce
//!    the Fig-5 scaling story (quadratic attention vs linear GSPN scan).
//!
//! Run: `make artifacts && cargo run --release --example highres_diffusion -- \
//!        [--train-steps 60]`

use gspn2::gpusim::{Backend, DeviceSpec, DiffusionModel};
use gspn2::runtime::{artifacts_available, Engine, Value};
use gspn2::train::train_denoiser;
use gspn2::util::cli::Args;
use gspn2::util::Rng;
use gspn2::Tensor;

/// DDPM schedule (must match python/compile/model.py::ddpm_alphas).
fn ddpm_schedule(steps: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut betas = Vec::with_capacity(steps);
    for i in 0..steps {
        betas.push(1e-4 + (0.02 - 1e-4) * i as f64 / (steps - 1) as f64);
    }
    let mut alpha_bar = Vec::with_capacity(steps);
    let mut prod = 1.0;
    for b in &betas {
        prod *= 1.0 - b;
        alpha_bar.push(prod);
    }
    (betas, alpha_bar.clone(), alpha_bar.iter().map(|a| (1.0 - a).sqrt()).collect())
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let train_steps = args.usize_or("train-steps", 60);

    if artifacts_available("artifacts") {
        let engine = Engine::cpu("artifacts")?;
        println!("== 1. training the GSPN-2 denoiser ({train_steps} steps) ==");
        let report = train_denoiser(&engine, train_steps, (train_steps / 10).max(1), 7)?;
        println!(
            "epsilon-prediction loss: {:.4} -> {:.4}\n",
            report.curve.first().map(|l| l.loss).unwrap_or(0.0),
            report.final_train_loss
        );

        println!("== 2. DDPM reverse sampling via the fwd artifact (16x16, 100 steps) ==");
        sample(&engine)?;
    } else {
        println!("artifacts/ not built — skipping the PJRT phases; run `make artifacts`.");
    }

    println!("\n== 3. Fig-5 resolution sweep on the A100 simulator ==");
    let dev = DeviceSpec::a100_sxm4_80gb();
    let m = DiffusionModel::sdxl_like();
    println!(
        "{:>10} {:>14} {:>14} {:>12} {:>10}",
        "res", "SDXL(flash)", "GSPN-2", "speedup", "GSPN-1"
    );
    for res in [1024usize, 2048, 4096, 8192, 16384] {
        let flash = m.generate_s(&dev, res, Backend::SdxlFlash);
        let g2 = m.generate_s(&dev, res, Backend::Gspn2);
        let g1 = m.generate_s(&dev, res, Backend::Gspn1);
        println!(
            "{:>10} {:>12.1} s {:>12.2} s {:>11.0}x {:>8.1} s",
            format!("{res}x{res}"),
            flash,
            g2,
            flash / g2,
            g1
        );
    }
    println!("(paper: 32x at 4K, 93x at 16K; see EXPERIMENTS.md for the 16K caveat)");
    Ok(())
}

/// Full reverse diffusion with the trained-from-init denoiser artifact.
fn sample(engine: &Engine) -> anyhow::Result<()> {
    let name = "denoiser_fwd_r16_b4";
    let params = engine.initial_params(name)?;
    let steps = 100usize;
    let (betas, alpha_bar, _) = ddpm_schedule(steps);
    let mut rng = Rng::new(123);
    let mut x = Tensor::randn(&[4, 3, 16, 16], &mut rng, 1.0);
    let t0 = std::time::Instant::now();
    for ti in (0..steps).rev() {
        let mut inputs = params.clone();
        inputs.push(Value::F32(x.clone()));
        inputs.push(Value::F32(Tensor::full(&[4], ti as f32)));
        let eps = engine.run(name, &inputs)?.remove(0).into_f32()?;
        let beta = betas[ti];
        let ab = alpha_bar[ti];
        let a = 1.0 - beta;
        // x_{t-1} = 1/sqrt(a) (x - beta/sqrt(1-ab) eps) + sigma z
        let coef = beta / (1.0 - ab).sqrt();
        x = x
            .zip(&eps, |xv, ev| (xv - coef as f32 * ev) / (a as f32).sqrt());
        if ti > 0 {
            let z = Tensor::randn(&x.shape, &mut rng, 1.0);
            let sigma = beta.sqrt() as f32;
            x = x.zip(&z, |xv, zv| xv + sigma * zv);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "sampled 4 images in {dt:.1} s ({:.1} ms/denoise-step); output stats: \
         mean {:.3}, |max| {:.3}",
        dt * 1000.0 / steps as f64,
        x.mean(),
        x.abs_max()
    );
    // Render one channel of one sample as ASCII.
    println!("sample 0, channel 0:");
    let maxv = x.abs_max().max(1e-6);
    for r in 0..16 {
        let row: String = (0..16)
            .map(|cidx| {
                let v = x.at(&[0, 0, r, cidx]) / maxv;
                match ((v + 1.0) * 2.5) as i32 {
                    i32::MIN..=0 => ' ',
                    1 => '.',
                    2 => '+',
                    3 => '*',
                    _ => '#',
                }
            })
            .collect();
        println!("  {row}");
    }
    Ok(())
}
