//! Dense prediction (segmentation) through the full three-layer stack.
//!
//! The paper's §6 notes dense-prediction evaluation is under-explored;
//! this example trains the GSPN segmenter (per-pixel logits via a
//! pixel-shuffle decoder over GSPN blocks) on the synthetic 2-marker
//! Voronoi task — labels that *require* global context, since pixels far
//! from both markers can only be classified by propagating the marker
//! positions — and renders a predicted mask as ASCII art.
//!
//! Run: `make artifacts && cargo run --release --example dense_prediction`

use gspn2::runtime::{artifacts_available, Engine, Value};
use gspn2::train::{train_segmenter, VoronoiSeg};

fn main() -> anyhow::Result<()> {
    if !artifacts_available("artifacts") {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::cpu("artifacts")?;

    // Train for a few hundred steps (pixel CE on the Voronoi task).
    let steps = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200usize);
    let report = train_segmenter(&engine, steps, steps / 10, steps / 4, 7)?;
    println!(
        "\ntrained {steps} steps: loss {:.4}, pixel accuracy {:.1}%",
        report.final_train_loss,
        report.final_eval_acc * 100.0
    );

    // Render one prediction. The fwd artifact takes (params..., x).
    let entry = engine
        .manifest()
        .by_kind("segmenter")
        .first()
        .cloned()
        .cloned()
        .expect("segmenter fwd artifact");
    let params = engine.initial_params(&entry.name)?;
    let mut ds = VoronoiSeg::new(entry.meta_usize("img").unwrap_or(32), 99);
    let (x, labels) = ds.batch(entry.meta_usize("batch").unwrap_or(4));
    let mut inputs = params;
    inputs.push(Value::F32(x));
    let out = engine.run(&entry.name, &inputs)?;
    let logits = out[0].as_f32()?;
    let (classes, s) = (logits.shape[1], logits.shape[2]);

    println!("\nsample 0 — truth (left) vs *untrained* prediction (right):");
    for y in 0..s {
        let mut left = String::new();
        let mut right = String::new();
        for xx in 0..s {
            left.push(if labels[y * s + xx] == 0 { '.' } else { '#' });
            let mut best = 0;
            let mut bestv = f32::NEG_INFINITY;
            for c in 0..classes {
                let v = logits.at(&[0, c, y, xx]);
                if v > bestv {
                    bestv = v;
                    best = c;
                }
            }
            right.push(if best == 0 { '.' } else { '#' });
        }
        println!("  {left}   {right}");
    }
    println!(
        "\n(the trained parameters live inside the training loop's buffers; \
         rerun with more steps to watch pixel accuracy climb)"
    );
    Ok(())
}
