//! Adaptive kernel selection across deployment regimes (appendix B).
//!
//! The paper's appendix B suggests dynamically selecting between a
//! GSPN-1-like configuration and the full GSPN-2 based on input
//! dimensions and batch size. This example drives
//! `gspn2::gpusim::adaptive` across the four workload regimes the paper
//! profiles — diffusion latents, classifier towers, batch video, and
//! high-channel feature maps — on every modeled device, printing the
//! chosen configuration, the rules that fired, and the predicted gain.
//!
//! Run: `cargo run --release --example adaptive_kernels`

use gspn2::gpusim::adaptive::{choose, compare};
use gspn2::gpusim::{DeviceSpec, ScanWorkload};

struct Regime {
    name: &'static str,
    wl: ScanWorkload,
}

fn main() {
    let regimes = [
        Regime {
            name: "diffusion latent  (1x4x1024x1024, low occupancy)",
            wl: ScanWorkload::fwd(1, 4, 1024, 1024),
        },
        Regime {
            name: "classifier tower  (16x8x1024x1024, paper Fig 3)",
            wl: ScanWorkload::fwd(16, 8, 1024, 1024),
        },
        Regime {
            name: "batch video       (256x1x1024x1024, paper Fig S3)",
            wl: ScanWorkload::fwd(256, 1, 1024, 1024),
        },
        Regime {
            name: "wide features     (1x1152x1024x1024, paper Fig S4)",
            wl: ScanWorkload::fwd(1, 1152, 1024, 1024),
        },
        Regime {
            name: "single stream     (1x1x2048x2048, worst-case occupancy)",
            wl: ScanWorkload::fwd(1, 1, 2048, 2048),
        },
    ];

    for dev in DeviceSpec::all() {
        println!("== {} ({} SMs, {:.0} GB/s) ==", dev.name, dev.sms, dev.peak_bw_gbs);
        for r in &regimes {
            let (fixed, adaptive, choice) = compare(&dev, &r.wl);
            let cfg = &choice.cfg;
            println!(
                "  {:<55} fixed {:>8.3} ms -> adaptive {:>8.3} ms ({:>4.1}x)",
                r.name,
                fixed,
                adaptive,
                fixed / adaptive
            );
            println!(
                "      config: sram={} 2d={} proxy={} split={}",
                cfg.sram, cfg.blocks2d, cfg.proxy_ratio, cfg.split
            );
            for rule in &choice.rationale {
                println!("      rule:  {rule}");
            }
        }
        println!();
    }

    // Show the full decision for one shape, as a serving coordinator
    // would log it at batch time.
    let dev = DeviceSpec::a100_sxm4_80gb();
    let wl = ScanWorkload::fwd(1, 1, 2048, 2048);
    let choice = choose(&dev, &wl);
    println!("batch-time decision for 1x1x2048x2048 on {}:", dev.name);
    println!("  {:#?}", choice.cfg);
}
