//! Quickstart: the GSPN-2 operator in three views.
//!
//! 1. Pure-Rust compact GSPN unit (no artifacts needed): run the
//!    4-direction propagation on a toy image and show the global
//!    receptive field.
//! 2. The Eq. 4 linear-attention view: materialise the affinity matrix G
//!    and print one pixel's "attention map".
//! 3. If `make artifacts` has run: execute the fused Pallas kernel via
//!    the PJRT runtime and verify it against the Rust reference.
//!
//! Run: `cargo run --release --example quickstart`

use gspn2::runtime::{artifacts_available, Engine, Value};
use gspn2::scan::{attention_map, scan_l2r, CompactGspnUnit, Taps};
use gspn2::util::Rng;
use gspn2::Tensor;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0);

    // --- 1. the compact GSPN unit (GSPN-2 §4.2) on CPU ------------------
    println!("== compact GSPN unit (channel-shared weights, C_proxy=2) ==");
    let unit = CompactGspnUnit::init(&mut rng, 16, 2, 0, false);
    let mut x = Tensor::randn(&[1, 16, 16, 16], &mut rng, 1.0);
    let y = unit.forward(&x);
    println!("input  {:?} -> output {:?} ({} params)", x.shape, y.shape, unit.param_count());

    // Perturb the top-left corner; watch the bottom-right corner move.
    for c in 0..16 {
        *x.at_mut(&[0, c, 0, 0]) += 10.0;
    }
    let y2 = unit.forward(&x);
    let corner: f32 =
        (0..16).map(|c| (y.at(&[0, c, 15, 15]) - y2.at(&[0, c, 15, 15])).abs()).sum();
    println!("corner-to-corner influence after perturbation: {corner:.4} (global context!)\n");

    // --- 2. the linear-attention view (Eq. 4) ---------------------------
    println!("== Eq. 4 affinity view: |G| row of pixel (4, 7) as an 8x8 map ==");
    let h = 8;
    let w = 8;
    let a_raw = Tensor::randn(&[1, 1, 3, h, w], &mut rng, 0.7);
    let taps = Taps::normalize(&a_raw);
    let lam = Tensor::full(&[1, 1, h, w], 1.0);
    let amap = attention_map(&taps, &lam, 0, 0, 4, 7);
    let maxv = amap.abs_max().max(1e-9);
    for r in 0..h {
        let row: String = (0..w)
            .map(|i| {
                let v = amap.at(&[r, i]) / maxv;
                match (v * 4.0) as usize {
                    0 => " .",
                    1 => " +",
                    2 => " *",
                    3 => " #",
                    _ => " @",
                }
            })
            .collect();
        println!("  {row}");
    }
    println!("(mass concentrates near the query column and decays leftward)\n");

    // --- 3. the AOT bridge: Pallas kernel through PJRT -------------------
    if !artifacts_available("artifacts") {
        println!("artifacts/ not built — run `make artifacts` to see the PJRT path.");
        return Ok(());
    }
    println!("== fused Pallas kernel via PJRT (scan_h64w64c8n1) ==");
    let engine = Engine::cpu("artifacts")?;
    let x = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
    let a_raw = Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0);
    let lam = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
    let t0 = std::time::Instant::now();
    let outs = engine.run(
        "scan_h64w64c8n1",
        &[Value::F32(x.clone()), Value::F32(a_raw.clone()), Value::F32(lam.clone())],
    )?;
    let compile_and_run = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _ = engine.run(
        "scan_h64w64c8n1",
        &[Value::F32(x.clone()), Value::F32(a_raw.clone()), Value::F32(lam.clone())],
    )?;
    let warm = t1.elapsed();
    let got = outs[0].as_f32()?;
    let want = scan_l2r(&x, &Taps::normalize(&a_raw), &lam, 0);
    println!(
        "PJRT vs Rust reference: max |diff| = {:.2e}  (cold {:.0} ms, warm {:.1} ms)",
        got.max_abs_diff(&want),
        compile_and_run.as_secs_f64() * 1e3,
        warm.as_secs_f64() * 1e3
    );
    println!("quickstart OK");
    Ok(())
}
