//! Segment-parallel scan decomposition: overhead and crossover sweep.
//!
//! Measures the CPU reference of the §5.1 low-occupancy decomposition
//! (`gspn2::scan::split`) against the sequential scan across segment
//! counts and thread counts. Findings (recorded in EXPERIMENTS.md §Perf):
//!
//! * the carry-only two-phase form costs ~0.75-0.95x of sequential
//!   throughput in pure overhead (the extra 3-flop correction pass);
//! * the banded *operator* form (see `segment_transfer`) costs O(s) extra
//!   work per column and was 4-30x slower — it only pays on massively
//!   parallel hardware, which is exactly the GPU regime the simulator's
//!   `KernelConfig::split` models and the adaptive policy selects;
//! * thread scaling requires multiple cores; `t>1` submits at most `t`
//!   jobs to the process-wide shared `ThreadPool` (no per-call spawns),
//!   so on a single-core host the t>1 rows show pure queueing overhead.
//!
//! Run: `cargo run --release --example split_sweep`

use gspn2::scan::{scan_l2r, scan_l2r_split, Taps};
use gspn2::util::bench::black_box;
use gspn2::util::Rng;
use gspn2::Tensor;
use std::time::Instant;

fn main() {
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("host parallelism: {host}\n");
    let mut rng = Rng::new(0);
    for (c, h, w) in [(1usize, 256usize, 256usize), (1, 512, 2048), (4, 512, 512)] {
        let x = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let reps = (50_000_000 / (c * h * w)).clamp(3, 50);
        let t0 = Instant::now();
        for _ in 0..reps {
            black_box(scan_l2r(&x, &a, &lam, 0));
        }
        let seq = t0.elapsed().as_secs_f64() / reps as f64;
        println!("c{c} {h}x{w}: sequential {:.3} ms", seq * 1e3);
        for segs in [8usize, 32] {
            for t in [1usize, host.min(8)] {
                let t0 = Instant::now();
                for _ in 0..reps {
                    black_box(scan_l2r_split(&x, &a, &lam, segs, t));
                }
                let el = t0.elapsed().as_secs_f64() / reps as f64;
                println!(
                    "  segs={segs:<3} t={t}: {:.3} ms ({:.2}x vs seq)",
                    el * 1e3,
                    seq / el
                );
            }
        }
    }
}
