//! Serving example (the paper's deployment scenario): drive the
//! coordinator with an open-loop Poisson trace of scan requests across
//! two shape buckets, then report latency percentiles, throughput, and
//! batching behaviour — plus a max-throughput closed-loop phase.
//!
//! Run: `make artifacts && cargo run --release --example serve_images -- \
//!        [--rate 100] [--requests 200] [--workers 2] [--max-batch 4]`

use std::time::Instant;

use gspn2::config::{Config, ServeConfig};
use gspn2::coordinator::{generate_trace, Coordinator, SubmitError, TraceConfig};
use gspn2::runtime::artifacts_available;
use gspn2::util::cli::Args;

fn main() -> anyhow::Result<()> {
    if !artifacts_available("artifacts") {
        eprintln!("artifacts/ not built — run `make artifacts` first");
        return Ok(());
    }
    let args = Args::parse(std::env::args().skip(1));
    let mut cfg = Config::from_args(&args).map_err(|e| anyhow::anyhow!(e))?;
    if args.get("rate").is_none() {
        cfg.serve.rate_rps = 100.0;
    }
    if args.get("requests").is_none() {
        cfg.serve.requests = 200;
    }

    println!("== phase 1: open-loop Poisson trace ==");
    open_loop(&cfg.serve)?;

    println!("\n== phase 2: closed-loop max throughput (single bucket) ==");
    closed_loop(&cfg.serve)?;
    Ok(())
}

fn open_loop(serve: &ServeConfig) -> anyhow::Result<()> {
    let coord = Coordinator::start(serve)?;
    let trace = generate_trace(&TraceConfig {
        rate_rps: serve.rate_rps,
        requests: serve.requests,
        seed: serve.seed,
        ..TraceConfig::default()
    });
    println!(
        "replaying {} requests at ~{:.0} rps ({} workers, max_batch {}, max_wait {} µs)",
        trace.len(),
        serve.rate_rps,
        serve.workers,
        serve.max_batch,
        serve.max_wait_us
    );
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rejected = 0;
    for ev in trace {
        let el = t0.elapsed();
        if ev.at > el {
            std::thread::sleep(ev.at - el);
        }
        match coord.submit_scan(ev.x, ev.a_raw, ev.lam, 0) {
            Ok(rx) => pending.push(rx),
            Err(
                SubmitError::Backpressure | SubmitError::Shed | SubmitError::Quota(_),
            ) => rejected += 1,
            Err(e) => return Err(e.into()),
        }
    }
    let mut ok = 0;
    for rx in pending {
        if rx.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let m = coord.shutdown();
    println!("completed {ok}, rejected-at-admission {rejected}");
    println!("{}", m.report());
    Ok(())
}

fn closed_loop(serve: &ServeConfig) -> anyhow::Result<()> {
    use gspn2::util::Rng;
    use gspn2::Tensor;
    let coord = Coordinator::start(serve)?;
    let mut rng = Rng::new(1);
    let total = 200usize;
    let inflight_cap = 32usize;
    let mut inflight = std::collections::VecDeque::new();
    let t0 = Instant::now();
    let mut done = 0usize;
    let mut submitted = 0usize;
    while done < total {
        while submitted < total && inflight.len() < inflight_cap {
            let x = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
            let a = Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0);
            let lam = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
            match coord.submit_scan(x, a, lam, 0) {
                Ok(rx) => {
                    inflight.push_back(rx);
                    submitted += 1;
                }
                Err(
                    SubmitError::Backpressure | SubmitError::Shed | SubmitError::Quota(_),
                ) => break,
                Err(e) => return Err(e.into()),
            }
        }
        if let Some(rx) = inflight.pop_front() {
            let _ = rx.recv();
            done += 1;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let m = coord.shutdown();
    println!(
        "{total} requests in {secs:.2} s -> {:.1} req/s sustained (mean batch {:.2})",
        total as f64 / secs,
        m.batch_sizes.mean()
    );
    println!("{}", m.report());
    Ok(())
}
